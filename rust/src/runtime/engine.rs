//! The PJRT execution engine: compile-once, execute-many surface
//! artifacts, with batch bucketing.
//!
//! One [`Engine`] owns a PJRT CPU client and a compiled executable per
//! static batch bucket (1 / 16 / 256 / 2048). An evaluation request of
//! `B` configs is decomposed greedily across the buckets
//! ([`super::shapes::plan_buckets`]): exact chunks of the largest
//! fitting bucket plus at most one padded call for the remainder, so an
//! odd batch never executes a whole wide bucket of padding. This is the
//! L3 hot path: the whole Figure-1 atlas and every staged-test round of
//! every tuning session funnels through [`Engine::evaluate_prepared`] or
//! the multi-request [`Engine::evaluate_coalesced`].
//!
//! # Coalesced execution
//!
//! [`Engine::evaluate_coalesced`] serves *many* logical requests in one
//! pass: requests sharing the same [`PreparedCall`] (pointer identity —
//! use [`Engine::prepare_cached`] so equal bindings share one prepared
//! set) are concatenated and bucket-planned **together**, then the
//! results are split back per request by row range. This is how the
//! multi-session scheduler turns 8 concurrent tuning rounds of 32 rows
//! each into a single 256-bucket execute instead of eight partial-width
//! calls. [`Engine::stats`] accounts both sides of the funnel: logical
//! `requests`/`rows_requested` in, physical `execute_calls`/
//! `rows_executed` (padding included) out.
//!
//! The engine is `Send + Sync` (telemetry is atomic; PJRT objects are
//! thread-safe by the PJRT C API contract), so experiments can share
//! one compiled engine across session threads via `Arc<Engine>`.

use super::shapes::{self, BUCKETS, D_PAD, E_DIM, W_DIM};
use crate::error::{ActsError, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Per-SUT surface parameter blocks, flattened row-major (f32), in the
/// artifact's input order minus the per-call inputs (`u`, `w`, `e`).
/// Sizes must match `shapes::INPUT_SPEC`; [`SurfaceParams::validate`]
/// checks them.
#[derive(Clone, Debug, PartialEq)]
pub struct SurfaceParams {
    /// Basis weights per workload feature: (4, D, W).
    pub m: Vec<f32>,
    /// Step-basis slopes: (D,).
    pub step_s: Vec<f32>,
    /// Step-basis thresholds: (D,).
    pub step_t: Vec<f32>,
    /// Interaction matrices per workload feature: (W, D, D).
    pub qs: Vec<f32>,
    /// RBF centers: (J, D).
    pub centers: Vec<f32>,
    /// RBF inverse widths: (J,).
    pub inv_rho2: Vec<f32>,
    /// Bump amplitudes per workload feature: (J, W).
    pub amps_w: Vec<f32>,
    /// Stacked cliff+gate directions: (R+G, D).
    pub dirs: Vec<f32>,
    /// Cliff thresholds: (R,).
    pub cliff_tau: Vec<f32>,
    /// Cliff steepness: (R,).
    pub cliff_kappa: Vec<f32>,
    /// Cliff gains per workload feature: (R, W).
    pub cliff_gain_w: Vec<f32>,
    /// Cliff gains per deployment feature: (R, E).
    pub cliff_gain_e: Vec<f32>,
    /// Gate thresholds: (G,).
    pub gate_tau: Vec<f32>,
    /// Gate steepness: (G,).
    pub gate_kappa: Vec<f32>,
    /// Pre-sigmoid gate floors per workload feature: (G, W).
    pub gate_floor_w: Vec<f32>,
    /// Deployment scale weights: (E,).
    pub dep_w: Vec<f32>,
    /// Head constants [t_scale, lat0, lat1, t_sat].
    pub consts: [f32; 4],
}

impl SurfaceParams {
    /// All-zero blocks (neutral surface) — builders start from this.
    pub fn zeros() -> SurfaceParams {
        let len = |name: &str| {
            let idx = shapes::INPUT_SPEC.iter().position(|(n, _)| *n == name).expect("name");
            shapes::len_for(idx, 1)
        };
        SurfaceParams {
            m: vec![0.0; len("m")],
            step_s: vec![0.0; len("step_s")],
            step_t: vec![0.0; len("step_t")],
            qs: vec![0.0; len("qs")],
            centers: vec![0.0; len("centers")],
            inv_rho2: vec![0.1; len("inv_rho2")],
            amps_w: vec![0.0; len("amps_w")],
            dirs: vec![0.0; len("dirs")],
            cliff_tau: vec![0.0; len("cliff_tau")],
            cliff_kappa: vec![0.0; len("cliff_kappa")],
            cliff_gain_w: vec![0.0; len("cliff_gain_w")],
            cliff_gain_e: vec![0.0; len("cliff_gain_e")],
            gate_tau: vec![0.0; len("gate_tau")],
            gate_kappa: vec![0.0; len("gate_kappa")],
            gate_floor_w: vec![0.0; len("gate_floor_w")],
            dep_w: vec![0.0; len("dep_w")],
            consts: [1.0, 0.0, 0.0, 1.0],
        }
    }

    /// Field slices in artifact order (excluding u/w/e), with their
    /// input-spec index.
    pub fn fields(&self) -> [(usize, &[f32]); 17] {
        [
            (3, &self.m),
            (4, &self.step_s),
            (5, &self.step_t),
            (6, &self.qs),
            (7, &self.centers),
            (8, &self.inv_rho2),
            (9, &self.amps_w),
            (10, &self.dirs),
            (11, &self.cliff_tau),
            (12, &self.cliff_kappa),
            (13, &self.cliff_gain_w),
            (14, &self.cliff_gain_e),
            (15, &self.gate_tau),
            (16, &self.gate_kappa),
            (17, &self.gate_floor_w),
            (18, &self.dep_w),
            (19, &self.consts),
        ]
    }

    /// Check every block length against the artifact spec.
    pub fn validate(&self) -> Result<()> {
        for (idx, slice) in self.fields() {
            let want = shapes::len_for(idx, 1);
            if slice.len() != want {
                return Err(ActsError::InvalidArg(format!(
                    "SurfaceParams.{}: {} elements, artifact wants {}",
                    shapes::INPUT_SPEC[idx].0,
                    slice.len(),
                    want
                )));
            }
        }
        Ok(())
    }
}

/// One evaluated configuration's simulated measurement.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Perf {
    /// Throughput, ops/sec (the maximization target).
    pub throughput: f64,
    /// Mean request latency, ms.
    pub latency: f64,
}

/// One logical evaluation request for [`Engine::evaluate_coalesced`]:
/// padded config rows to run against one prepared constant set.
/// Requests whose `prepared` is the *same object* coalesce into shared
/// bucket executes.
pub struct EvalRequest<'a> {
    /// Device-resident constants the rows evaluate against.
    pub prepared: &'a PreparedCall,
    /// Padded `[f32; D_PAD]` unit rows (may be empty).
    pub configs: &'a [Vec<f32>],
}

/// Hot-path telemetry counters (see [`Engine::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// PJRT `execute` calls issued.
    pub execute_calls: u64,
    /// Config rows executed, bucket padding included.
    pub rows_executed: u64,
    /// Logical evaluation requests served: one per
    /// [`Engine::evaluate_prepared`] call, one per [`EvalRequest`] in a
    /// coalesced execute. `requests > execute_calls` is the signature
    /// of cross-request coalescing; `requests < execute_calls` of
    /// multi-call plans.
    pub requests: u64,
    /// Source rows requested, before planning and padding.
    pub rows_requested: u64,
}

/// Compile-once, execute-many PJRT engine.
pub struct Engine {
    client: xla::PjRtClient,
    /// (bucket, executable), ascending bucket order.
    execs: Vec<(usize, xla::PjRtLoadedExecutable)>,
    artifacts_dir: PathBuf,
    /// Number of `execute` calls issued (hot-path telemetry).
    calls: AtomicU64,
    /// Number of config rows evaluated (incl. padding).
    rows: AtomicU64,
    /// Number of logical evaluation requests served.
    requests: AtomicU64,
    /// Number of source rows requested (pre-padding).
    rows_requested: AtomicU64,
    /// Content-keyed prepared-constant cache ([`Engine::prepare_cached`]):
    /// equal (params, w, e) bindings share one device-resident set, which
    /// is what makes their requests coalescible by pointer identity.
    prepare_cache: Mutex<HashMap<Vec<u32>, Arc<PreparedCall>>>,
}

// SAFETY: two obligations are being claimed here.
// (1) PJRT side: the C API requires clients, loaded executables and
//     buffers to be usable from any thread concurrently (the CPU
//     client serialises internally where it must), and every Engine
//     method takes `&self`; our only interior mutability is the
//     atomic telemetry counters and the Mutex-guarded prepare cache
//     (whose values are `Arc<PreparedCall>`, themselves Send + Sync).
// (2) Wrapper side: the vendored `xla` binding must hold plain FFI
//     handles for the client/executable types (no thread-unsafe shared
//     ownership such as `Rc` refcounts cloned per call) — this is the
//     part the compiler cannot see past, and it MUST be re-audited
//     whenever the binding is vendored or upgraded. Per-call wrapper
//     objects (literals, buffers) are created, used and dropped within
//     a single `evaluate_*` call on one thread and never cross threads.
// Together these let experiments run whole tuning sessions in parallel
// threads over one `Arc<Engine>` instead of compiling the bucket
// ladder once per thread.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load and compile every bucket artifact from `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu()?;
        let mut execs = Vec::with_capacity(BUCKETS.len());
        for &bucket in BUCKETS.iter() {
            let path = dir.join(shapes::artifact_name(bucket));
            if !path.exists() {
                return Err(ActsError::Artifact(format!(
                    "{} missing — run `make artifacts` first",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| ActsError::Artifact("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            execs.push((bucket, exe));
        }
        Ok(Engine {
            client,
            execs,
            artifacts_dir: dir,
            calls: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            rows_requested: AtomicU64::new(0),
            prepare_cache: Mutex::new(HashMap::new()),
        })
    }

    /// The artifacts directory this engine loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Telemetry counters so far: logical requests/rows in, physical
    /// execute calls/rows (padding included) out.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            execute_calls: self.calls.load(Ordering::Relaxed),
            rows_executed: self.rows.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            rows_requested: self.rows_requested.load(Ordering::Relaxed),
        }
    }

    /// Evaluate `configs` (each a padded `[f32; D_PAD]` unit vector) for
    /// one SUT surface under workload features `w` and deployment
    /// features `e`. Any `configs.len() >= 1` is accepted: requests are
    /// decomposed greedily across the buckets (see
    /// [`Engine::evaluate_prepared`]).
    ///
    /// One-shot convenience wrapper around [`Engine::prepare`] +
    /// [`Engine::evaluate_prepared`]; repeated callers (the manipulator,
    /// the benches) should prepare once — the §Perf pass showed the
    /// per-call upload of the constant parameter blocks (~150 KiB)
    /// dominating small-batch latency.
    pub fn evaluate(
        &self,
        params: &SurfaceParams,
        w: &[f32],
        e: &[f32],
        configs: &[Vec<f32>],
    ) -> Result<Vec<Perf>> {
        let prepared = self.prepare(params, w, e)?;
        self.evaluate_prepared(&prepared, configs)
    }

    /// Upload the constant inputs (w, e, and every parameter block) to
    /// device-resident buffers, once per bucket. The returned
    /// [`PreparedCall`] is reusable for any number of
    /// [`Engine::evaluate_prepared`] calls against this engine.
    pub fn prepare(&self, params: &SurfaceParams, w: &[f32], e: &[f32]) -> Result<PreparedCall> {
        if w.len() != W_DIM || e.len() != E_DIM {
            return Err(ActsError::InvalidArg(format!(
                "w has {} (want {W_DIM}), e has {} (want {E_DIM})",
                w.len(),
                e.len()
            )));
        }
        params.validate()?;
        let devices = self.client.devices();
        let device = &devices[0];
        let mut per_bucket = Vec::with_capacity(BUCKETS.len());
        // NB: the CPU client's CopyFromLiteral is ASYNC — a worker thread
        // reads from the Literal after buffer_from_host_literal returns,
        // so every uploaded literal is kept alive inside PreparedCall.
        let mut literals = Vec::new();
        for &bucket in BUCKETS.iter() {
            let mut upload = |idx: usize, data: &[f32]| -> Result<xla::PjRtBuffer> {
                let dims: Vec<i64> =
                    shapes::dims_for(idx, bucket).iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data).reshape(&dims)?;
                let buf = self.client.buffer_from_host_literal(Some(device), &lit)?;
                literals.push(lit);
                Ok(buf)
            };
            let mut bufs = Vec::with_capacity(shapes::INPUT_SPEC.len() - 1);
            bufs.push(upload(1, w)?);
            bufs.push(upload(2, e)?);
            for (idx, slice) in params.fields() {
                bufs.push(upload(idx, slice)?);
            }
            per_bucket.push(bufs);
        }
        // force every async H2D copy to complete before returning: a
        // PreparedCall dropped mid-transfer would free the source
        // literals under the copy thread (observed SIGSEGV otherwise)
        for bufs in &per_bucket {
            for buf in bufs {
                let _ = buf.to_literal_sync()?;
            }
        }
        Ok(PreparedCall { per_bucket, _literals: literals })
    }

    /// As [`Engine::prepare`], but content-cached: equal (params, w, e)
    /// bindings (bit-compared) share one device-resident constant set.
    /// Besides skipping the ~150 KiB re-upload per deployment, the
    /// shared `Arc` gives same-binding callers *pointer-identical*
    /// prepared constants — the coalescing key of
    /// [`Engine::evaluate_coalesced`].
    pub fn prepare_cached(
        &self,
        params: &SurfaceParams,
        w: &[f32],
        e: &[f32],
    ) -> Result<Arc<PreparedCall>> {
        let mut key: Vec<u32> = Vec::with_capacity(W_DIM + E_DIM + 64);
        key.extend(w.iter().map(|x| x.to_bits()));
        key.extend(e.iter().map(|x| x.to_bits()));
        for (_, slice) in params.fields() {
            key.extend(slice.iter().map(|x| x.to_bits()));
        }
        if let Some(hit) = self.prepare_cache.lock().expect("prepare cache").get(&key) {
            return Ok(hit.clone());
        }
        // prepare outside the lock (it blocks on device uploads); a
        // concurrent racer keeps whichever entry landed first so every
        // caller still ends up pointer-identical
        let fresh = Arc::new(self.prepare(params, w, e)?);
        let mut cache = self.prepare_cache.lock().expect("prepare cache");
        Ok(cache.entry(key).or_insert(fresh).clone())
    }

    /// Evaluate against a prepared constant set. Only the config batch
    /// is uploaded per call.
    ///
    /// The batch is split greedily across the compiled buckets
    /// ([`shapes::plan_buckets`]): exact chunks of the largest fitting
    /// bucket, with at most one padded call for the remainder — a B=40
    /// request executes as 3×16 rows, not one 256-row call. The device
    /// handle is resolved once per request and one upload scratch
    /// buffer is reused across the plan's calls.
    pub fn evaluate_prepared(
        &self,
        prepared: &PreparedCall,
        configs: &[Vec<f32>],
    ) -> Result<Vec<Perf>> {
        if configs.is_empty() {
            return Ok(Vec::new());
        }
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rows_requested.fetch_add(configs.len() as u64, Ordering::Relaxed);
        let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
        self.evaluate_rows(prepared, &rows)
    }

    /// Serve many logical requests as shared bucket executes: requests
    /// against the *same* [`PreparedCall`] object are concatenated (in
    /// request order) and bucket-planned together, then the results are
    /// split back per request by row range. Returns one `Vec<Perf>` per
    /// request, in request order.
    ///
    /// This is the cross-session batching primitive: 8 tuning sessions
    /// staging 32 rows each against one shared binding execute as a
    /// single 256-bucket call instead of eight partial-width calls.
    /// Requests against distinct prepared sets (different SUT surfaces,
    /// workloads or deployments) stay separate plans — per-call
    /// constants cannot mix — but still share this one entry point.
    pub fn evaluate_coalesced(&self, requests: &[EvalRequest<'_>]) -> Result<Vec<Vec<Perf>>> {
        self.requests.fetch_add(requests.len() as u64, Ordering::Relaxed);
        let requested: u64 = requests.iter().map(|r| r.configs.len() as u64).sum();
        self.rows_requested.fetch_add(requested, Ordering::Relaxed);
        let keys: Vec<usize> =
            requests.iter().map(|r| r.prepared as *const PreparedCall as usize).collect();
        let mut out: Vec<Vec<Perf>> = requests.iter().map(|_| Vec::new()).collect();
        for group in group_by_key(&keys) {
            let rows: Vec<&[f32]> = group
                .iter()
                .flat_map(|&i| requests[i].configs.iter().map(|c| c.as_slice()))
                .collect();
            if rows.is_empty() {
                continue;
            }
            let perfs = self.evaluate_rows(requests[group[0]].prepared, &rows)?;
            let mut offset = 0usize;
            for &i in &group {
                let n = requests[i].configs.len();
                out[i] = perfs[offset..offset + n].to_vec();
                offset += n;
            }
            debug_assert_eq!(offset, rows.len(), "demux must consume every row");
        }
        Ok(out)
    }

    /// Shared core of the evaluate paths: validate, plan, execute.
    fn evaluate_rows(&self, prepared: &PreparedCall, rows: &[&[f32]]) -> Result<Vec<Perf>> {
        for (i, r) in rows.iter().enumerate() {
            if r.len() != D_PAD {
                return Err(ActsError::InvalidArg(format!(
                    "config {i} has {} lanes, want {D_PAD}",
                    r.len()
                )));
            }
        }
        // one devices() resolution (it allocates a Vec) per request, not
        // per chunk
        let devices = self.client.devices();
        let device = &devices[0];
        let mut scratch: Vec<f32> = Vec::new();
        let mut out = Vec::with_capacity(rows.len());
        let mut offset = 0usize;
        for bucket in shapes::plan_buckets(rows.len()) {
            let take = bucket.min(rows.len() - offset);
            let chunk = &rows[offset..offset + take];
            offset += take;
            out.extend(self.evaluate_chunk(prepared, chunk, bucket, device, &mut scratch)?);
        }
        debug_assert_eq!(offset, rows.len(), "plan must consume every row");
        Ok(out)
    }

    /// Execute one planned call: `configs.len() <= bucket` rows, padded
    /// up to `bucket` with copies of row 0 (cheap, valid data).
    fn evaluate_chunk(
        &self,
        prepared: &PreparedCall,
        configs: &[&[f32]],
        bucket: usize,
        device: &xla::PjRtDevice,
        scratch: &mut Vec<f32>,
    ) -> Result<Vec<Perf>> {
        let b = configs.len();
        debug_assert!(b >= 1 && b <= bucket);
        let bucket_pos = BUCKETS.iter().position(|&k| k == bucket).expect("planned bucket");
        let exe = &self.execs[bucket_pos].1;
        let consts = &prepared.per_bucket[bucket_pos];

        // u: bucket rows in the reusable scratch buffer
        scratch.clear();
        scratch.reserve(bucket * D_PAD);
        for c in configs {
            scratch.extend_from_slice(c);
        }
        for _ in b..bucket {
            scratch.extend_from_slice(configs[0]);
        }
        // NB: go through a Literal (buffer_from_host_buffer may zero-copy
        // and alias the host memory) and keep `u_lit` alive until the
        // output sync — the CPU client's CopyFromLiteral reads it from a
        // worker thread. The Literal owns its copy, so `scratch` is free
        // for the plan's next call immediately.
        let u_lit = xla::Literal::vec1(&scratch[..]).reshape(&[bucket as i64, D_PAD as i64])?;
        let u_buf = self.client.buffer_from_host_literal(Some(device), &u_lit)?;
        // await the async H2D copy (readback sync; CopyRawToHost is not
        // implemented on this CPU client) so u_lit cannot be freed under
        // the copy thread on any early-return path
        let _ = u_buf.to_literal_sync()?;

        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(consts.len() + 1);
        inputs.push(&u_buf);
        inputs.extend(consts.iter());

        let result = exe.execute_b::<&xla::PjRtBuffer>(&inputs)?;
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(bucket as u64, Ordering::Relaxed);
        let tuple = result[0][0].to_literal_sync()?;
        // the output sync above also guarantees the input transfer is
        // done; only now may u_lit drop
        drop(u_lit);
        let (thr_lit, lat_lit) = tuple.to_tuple2()?;
        let thr = thr_lit.to_vec::<f32>()?;
        let lat = lat_lit.to_vec::<f32>()?;
        if thr.len() != bucket || lat.len() != bucket {
            return Err(ActsError::Artifact(format!(
                "artifact returned {} outputs for bucket {bucket}",
                thr.len()
            )));
        }
        Ok(thr[..b]
            .iter()
            .zip(&lat[..b])
            .map(|(&t, &l)| Perf { throughput: t as f64, latency: l as f64 })
            .collect())
    }
}

/// Stable grouping of equal keys preserving first-appearance order —
/// the request-coalescing kernel of [`Engine::evaluate_coalesced`],
/// also reused by the scheduler to group requests per engine. Returns,
/// for each distinct key in first-seen order, the indices that carry
/// it (each group ascending).
pub(crate) fn group_by_key(keys: &[usize]) -> Vec<Vec<usize>> {
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
    for (i, &k) in keys.iter().enumerate() {
        match groups.iter_mut().find(|(gk, _)| *gk == k) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((k, vec![i])),
        }
    }
    groups.into_iter().map(|(_, idxs)| idxs).collect()
}

/// Device-resident constant inputs (w, e, parameter blocks) for every
/// bucket — see [`Engine::prepare`].
pub struct PreparedCall {
    /// Buffers in artifact input order minus `u`, one set per bucket.
    per_bucket: Vec<Vec<xla::PjRtBuffer>>,
    /// Source literals, kept alive for the async device copies.
    _literals: Vec<xla::Literal>,
}

// SAFETY: after `Engine::prepare` returns, every buffer's H2D copy has
// completed (it syncs before handing the value back) and the buffers
// and literals are only ever read — PJRT buffers are usable from any
// thread per the C API contract. This makes per-SUT prepared constants
// movable into session worker threads.
unsafe impl Send for PreparedCall {}
unsafe impl Sync for PreparedCall {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_params_validate() {
        SurfaceParams::zeros().validate().unwrap();
    }

    #[test]
    fn validate_catches_wrong_length() {
        let mut p = SurfaceParams::zeros();
        p.qs.pop();
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("qs"), "{err}");
    }

    #[test]
    fn fields_cover_every_non_call_input() {
        let p = SurfaceParams::zeros();
        let mut idxs: Vec<usize> = p.fields().iter().map(|(i, _)| *i).collect();
        idxs.sort_unstable();
        assert_eq!(idxs, (3..20).collect::<Vec<_>>());
    }

    /// Compile-time guarantee behind parallel-session experiments: the
    /// engine and its prepared constants cross thread boundaries.
    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Engine>();
        assert_send_sync::<PreparedCall>();
    }

    #[test]
    fn group_by_key_preserves_order_and_coalesces() {
        // three bindings interleaved: groups appear in first-seen order,
        // indices ascend within each group
        assert_eq!(
            group_by_key(&[7, 9, 7, 7, 3, 9]),
            vec![vec![0, 2, 3], vec![1, 5], vec![4]]
        );
        assert_eq!(group_by_key(&[]), Vec::<Vec<usize>>::new());
        assert_eq!(group_by_key(&[1]), vec![vec![0]]);
        // all distinct: one singleton group per request
        assert_eq!(group_by_key(&[4, 5, 6]), vec![vec![0], vec![1], vec![2]]);
    }
    // engine execution itself (including the coalesced path) is covered
    // by the `runtime_golden` integration test (needs artifacts on disk)
}
