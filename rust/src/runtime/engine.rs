//! The execution engine front-end: compile-once (or premix-once),
//! execute-many surface evaluation, with cross-request coalescing.
//!
//! One [`Engine`] owns an [`ExecBackend`] — the PJRT bucket engine
//! ([`Engine::load`]) or the pure-`std` native CPU evaluator
//! ([`Engine::native`]) — plus everything backend-independent: request
//! validation, the content-keyed prepared-constant cache, cross-request
//! coalescing and the hot-path telemetry. This is the L3 hot path: the
//! whole Figure-1 atlas and every staged-test round of every tuning
//! session funnels through [`Engine::evaluate_prepared`] or the
//! multi-request [`Engine::evaluate_coalesced`].
//!
//! # Coalesced execution
//!
//! [`Engine::evaluate_coalesced`] serves *many* logical requests in one
//! pass: requests sharing the same [`PreparedCall`] (pointer identity —
//! use [`Engine::prepare_cached`] so equal bindings share one prepared
//! set) are concatenated and executed **together**, then the results
//! are split back per request by row range. This is how the
//! multi-session scheduler turns 8 concurrent tuning rounds of 32 rows
//! each into a single 256-row execute instead of eight partial-width
//! calls. [`Engine::stats`] accounts both sides of the funnel: logical
//! `requests`/`rows_requested` in, physical `execute_calls`/
//! `rows_executed` (padding included) out.
//!
//! The engine is `Send + Sync` by construction (the backend trait
//! requires it; telemetry is atomic; the prepare cache is mutex-
//! guarded), so experiments share one engine across session threads via
//! `Arc<Engine>` and the scheduler's pipelined tick executes on a
//! worker thread while staging continues on the scheduler thread.

use super::backend::{BackendKind, ExecBackend, Execution, PendingExecution, PreparedData};
use super::shapes::{self, D_PAD, E_DIM, W_DIM};
use crate::error::{ActsError, Result};
use crate::util::rng::Rng64;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::Duration;

/// Per-SUT surface parameter blocks, flattened row-major (f32), in the
/// artifact's input order minus the per-call inputs (`u`, `w`, `e`).
/// Sizes must match `shapes::INPUT_SPEC`; [`SurfaceParams::validate`]
/// checks them.
#[derive(Clone, Debug, PartialEq)]
pub struct SurfaceParams {
    /// Basis weights per workload feature: (4, D, W).
    pub m: Vec<f32>,
    /// Step-basis slopes: (D,).
    pub step_s: Vec<f32>,
    /// Step-basis thresholds: (D,).
    pub step_t: Vec<f32>,
    /// Interaction matrices per workload feature: (W, D, D).
    pub qs: Vec<f32>,
    /// RBF centers: (J, D).
    pub centers: Vec<f32>,
    /// RBF inverse widths: (J,).
    pub inv_rho2: Vec<f32>,
    /// Bump amplitudes per workload feature: (J, W).
    pub amps_w: Vec<f32>,
    /// Stacked cliff+gate directions: (R+G, D).
    pub dirs: Vec<f32>,
    /// Cliff thresholds: (R,).
    pub cliff_tau: Vec<f32>,
    /// Cliff steepness: (R,).
    pub cliff_kappa: Vec<f32>,
    /// Cliff gains per workload feature: (R, W).
    pub cliff_gain_w: Vec<f32>,
    /// Cliff gains per deployment feature: (R, E).
    pub cliff_gain_e: Vec<f32>,
    /// Gate thresholds: (G,).
    pub gate_tau: Vec<f32>,
    /// Gate steepness: (G,).
    pub gate_kappa: Vec<f32>,
    /// Pre-sigmoid gate floors per workload feature: (G, W).
    pub gate_floor_w: Vec<f32>,
    /// Deployment scale weights: (E,).
    pub dep_w: Vec<f32>,
    /// Head constants [t_scale, lat0, lat1, t_sat].
    pub consts: [f32; 4],
}

impl SurfaceParams {
    /// All-zero blocks (neutral surface) — builders start from this.
    pub fn zeros() -> SurfaceParams {
        let len = |name: &str| {
            let idx = shapes::INPUT_SPEC.iter().position(|(n, _)| *n == name).expect("name");
            shapes::len_for(idx, 1)
        };
        SurfaceParams {
            m: vec![0.0; len("m")],
            step_s: vec![0.0; len("step_s")],
            step_t: vec![0.0; len("step_t")],
            qs: vec![0.0; len("qs")],
            centers: vec![0.0; len("centers")],
            inv_rho2: vec![0.1; len("inv_rho2")],
            amps_w: vec![0.0; len("amps_w")],
            dirs: vec![0.0; len("dirs")],
            cliff_tau: vec![0.0; len("cliff_tau")],
            cliff_kappa: vec![0.0; len("cliff_kappa")],
            cliff_gain_w: vec![0.0; len("cliff_gain_w")],
            cliff_gain_e: vec![0.0; len("cliff_gain_e")],
            gate_tau: vec![0.0; len("gate_tau")],
            gate_kappa: vec![0.0; len("gate_kappa")],
            gate_floor_w: vec![0.0; len("gate_floor_w")],
            dep_w: vec![0.0; len("dep_w")],
            consts: [1.0, 0.0, 0.0, 1.0],
        }
    }

    /// Field slices in artifact order (excluding u/w/e), with their
    /// input-spec index.
    pub fn fields(&self) -> [(usize, &[f32]); 17] {
        [
            (3, &self.m),
            (4, &self.step_s),
            (5, &self.step_t),
            (6, &self.qs),
            (7, &self.centers),
            (8, &self.inv_rho2),
            (9, &self.amps_w),
            (10, &self.dirs),
            (11, &self.cliff_tau),
            (12, &self.cliff_kappa),
            (13, &self.cliff_gain_w),
            (14, &self.cliff_gain_e),
            (15, &self.gate_tau),
            (16, &self.gate_kappa),
            (17, &self.gate_floor_w),
            (18, &self.dep_w),
            (19, &self.consts),
        ]
    }

    /// Check every block length against the artifact spec.
    pub fn validate(&self) -> Result<()> {
        for (idx, slice) in self.fields() {
            let want = shapes::len_for(idx, 1);
            if slice.len() != want {
                return Err(ActsError::InvalidArg(format!(
                    "SurfaceParams.{}: {} elements, artifact wants {}",
                    shapes::INPUT_SPEC[idx].0,
                    slice.len(),
                    want
                )));
            }
        }
        Ok(())
    }
}

/// One evaluated configuration's simulated measurement.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Perf {
    /// Throughput, ops/sec (the maximization target).
    pub throughput: f64,
    /// Mean request latency, ms.
    pub latency: f64,
}

/// One logical evaluation request for [`Engine::evaluate_coalesced`]:
/// padded config rows to run against one prepared constant set.
/// Requests whose `prepared` is the *same object* coalesce into shared
/// executes.
pub struct EvalRequest<'a> {
    /// Backend-resident constants the rows evaluate against.
    pub prepared: &'a PreparedCall,
    /// Padded `[f32; D_PAD]` unit rows (may be empty).
    pub configs: &'a [Vec<f32>],
}

/// Hot-path telemetry counters (see [`Engine::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Physical backend execute calls issued (PJRT: one per planned
    /// bucket chunk; native: one per batch).
    pub execute_calls: u64,
    /// Config rows executed, bucket padding included.
    pub rows_executed: u64,
    /// Logical evaluation requests served: one per
    /// [`Engine::evaluate_prepared`] call, one per [`EvalRequest`] in a
    /// coalesced execute. `requests > execute_calls` is the signature
    /// of cross-request coalescing; `requests < execute_calls` of
    /// multi-call plans.
    pub requests: u64,
    /// Source rows requested, before planning and padding.
    pub rows_requested: u64,
    /// Backend execute attempts issued by the engine front-end,
    /// including retries. On a fault-free run `attempts` equals the
    /// number of front-end execute invocations and `retries` is zero.
    pub attempts: u64,
    /// Attempts beyond the first for a call — each one is a transient
    /// backend fault the [`RetryPolicy`] absorbed.
    pub retries: u64,
    /// Executes killed by the [`RetryPolicy`] per-call deadline instead
    /// of being allowed to hang the calling lane.
    pub deadline_kills: u64,
    /// Streaming-mode submission flushes triggered by the batch-size
    /// threshold (the queue filled a full flush before the timeout).
    pub flushes_by_size: u64,
    /// Streaming-mode submission flushes triggered by the flush timeout
    /// (a partial batch aged out — latency bound, not width bound).
    pub flushes_by_timeout: u64,
    /// Peak number of submitted-but-not-absorbed rounds observed at
    /// once (a high-water gauge, not a delta: streaming concurrency
    /// depth). Barriered modes leave it at 0.
    pub peak_inflight: u64,
    /// Deadline-killed helper threads whose abandoned execute is still
    /// running at the time of the [`Engine::stats`] read (a live gauge,
    /// not a cumulative counter). Bounded by the engine's orphan cap.
    pub live_orphans: u64,
    /// SIMD lane width of the backend's row evaluator (1 = scalar; 8 =
    /// the native AVX2 f32x8 path). A property of the backend's
    /// construction-time dispatch, not a counter — surfaced so numeric
    /// drift across runs can be attributed to a dispatch change.
    pub simd_width: u64,
}

/// Retry/deadline policy for backend executes (see
/// [`Engine::set_retry_policy`]). Attempts are spaced by exponential
/// backoff with deterministic seeded jitter, so a faulted run retries
/// on an identical schedule every time; `deadline`, when set, bounds
/// each attempt's wall-clock and fails the call instead of wedging the
/// calling lane on a hung backend.
///
/// The policy only engages on `Err` from the backend: a fault-free run
/// takes the exact same single-execute path as a policy-less engine,
/// which is what keeps records bit-identical when retries are enabled
/// but nothing faults.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts per call (first try included); min 1.
    pub max_attempts: u32,
    /// Sleep before the first retry; doubles per further retry.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Seed for the deterministic backoff jitter stream.
    pub jitter_seed: u64,
    /// Per-attempt wall-clock bound. `None` runs the backend inline
    /// (zero overhead); `Some` runs it on a helper thread and abandons
    /// it on timeout.
    pub deadline: Option<Duration>,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(50),
            jitter_seed: 0,
            deadline: None,
        }
    }
}

/// Backend-resident constant inputs for one (params, w, e) binding —
/// see [`Engine::prepare`]. Type-erased over the engine's backend;
/// `Send + Sync` by the [`PreparedData`] trait obligation, so prepared
/// constants cross into the scheduler's execute worker thread.
pub struct PreparedCall {
    // Arc (not Box) so the deadline path can hand the payload to a
    // helper thread that may outlive the call it was spawned for.
    data: Arc<dyn PreparedData>,
}

impl PreparedCall {
    /// The backend-specific payload.
    pub(crate) fn data(&self) -> &dyn PreparedData {
        self.data.as_ref()
    }

    /// Shared handle for the deadline helper thread.
    fn data_arc(&self) -> Arc<dyn PreparedData> {
        Arc::clone(&self.data)
    }
}

/// Compile-once (or premix-once), execute-many engine front-end over a
/// pluggable [`ExecBackend`].
pub struct Engine {
    // Arc (not Box) so the deadline path can clone a handle into a
    // helper thread that may outlive the call it was spawned for.
    backend: Arc<dyn ExecBackend>,
    /// Number of physical execute calls issued (hot-path telemetry).
    calls: AtomicU64,
    /// Number of config rows evaluated (incl. padding).
    rows: AtomicU64,
    /// Number of logical evaluation requests served.
    requests: AtomicU64,
    /// Number of source rows requested (pre-padding).
    rows_requested: AtomicU64,
    /// Backend execute attempts, retries included.
    attempts: AtomicU64,
    /// Attempts beyond the first per call (absorbed transient faults).
    retries: AtomicU64,
    /// Executes killed by the per-call deadline.
    deadline_kills: AtomicU64,
    /// Streaming flushes by cause (size threshold vs timeout).
    flushes_by_size: AtomicU64,
    flushes_by_timeout: AtomicU64,
    /// High-water mark of concurrently in-flight submitted rounds.
    peak_inflight: AtomicU64,
    /// Deadline-killed helper threads abandoned mid-execute: kept so
    /// finished ones can be reaped (joined) instead of leaking, and so
    /// the live count can be capped and reported.
    orphans: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Retry/deadline policy for backend executes (None = fail fast,
    /// the historical behaviour).
    retry: RwLock<Option<RetryPolicy>>,
    /// Content-keyed prepared-constant cache ([`Engine::prepare_cached`]):
    /// equal (params, w, e) bindings share one backend-resident set, which
    /// is what makes their requests coalescible by pointer identity.
    prepare_cache: Mutex<HashMap<Vec<u32>, Arc<PreparedCall>>>,
}

impl Engine {
    /// Engine over an explicit backend.
    pub fn from_backend(backend: Box<dyn ExecBackend>) -> Engine {
        Engine {
            backend: Arc::from(backend),
            calls: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            rows_requested: AtomicU64::new(0),
            attempts: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            deadline_kills: AtomicU64::new(0),
            flushes_by_size: AtomicU64::new(0),
            flushes_by_timeout: AtomicU64::new(0),
            peak_inflight: AtomicU64::new(0),
            orphans: Mutex::new(Vec::new()),
            retry: RwLock::new(None),
            prepare_cache: Mutex::new(HashMap::new()),
        }
    }

    /// Load and compile every bucket artifact from `artifacts_dir` into
    /// a PJRT-backed engine.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        Ok(Engine::from_backend(Box::new(super::pjrt::PjrtBackend::load(artifacts_dir)?)))
    }

    /// Engine over the pure-`std` native CPU backend — no artifacts, no
    /// XLA binding; runs anywhere. Fails when `ACTS_NATIVE_THREADS` or
    /// `ACTS_NATIVE_SIMD` is set to something unusable (a typo must not
    /// silently run at a different parallelism or evaluator path, on
    /// any construction path — CLI, benches, `Lab::for_config`).
    pub fn native() -> Result<Engine> {
        Ok(Engine::from_backend(Box::new(super::native::NativeBackend::new()?)))
    }

    /// Resolve a [`BackendKind`] into an engine: `Pjrt` loads the
    /// artifacts (failing if it cannot), `Native` never touches them,
    /// and `Auto` tries PJRT first and falls back to native with a note
    /// on stderr — the "runs anywhere" default.
    pub fn from_kind(kind: BackendKind, artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        match kind {
            BackendKind::Pjrt => Engine::load(artifacts_dir),
            BackendKind::Native => Engine::native(),
            BackendKind::Auto => match Engine::load(artifacts_dir) {
                Ok(engine) => Ok(engine),
                Err(err) => {
                    eprintln!(
                        "acts: PJRT backend unavailable ({err}); using the native CPU backend"
                    );
                    Engine::native()
                }
            },
        }
    }

    /// The backend's registry name (`"pjrt"`, `"native"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Backend platform description (diagnostics).
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Telemetry counters so far: logical requests/rows in, physical
    /// execute calls/rows (padding included) out.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            execute_calls: self.calls.load(Ordering::Relaxed),
            rows_executed: self.rows.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            rows_requested: self.rows_requested.load(Ordering::Relaxed),
            attempts: self.attempts.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            deadline_kills: self.deadline_kills.load(Ordering::Relaxed),
            flushes_by_size: self.flushes_by_size.load(Ordering::Relaxed),
            flushes_by_timeout: self.flushes_by_timeout.load(Ordering::Relaxed),
            peak_inflight: self.peak_inflight.load(Ordering::Relaxed),
            live_orphans: self
                .orphans
                .lock()
                .expect("orphan registry")
                .iter()
                .filter(|h| !h.is_finished())
                .count() as u64,
            simd_width: self.backend.simd_width(),
        }
    }

    /// Record one streaming-mode submission flush and its cause (the
    /// batch-size threshold vs the flush timeout). Called by the
    /// streaming scheduler's drainer for each engine appearing in a
    /// flushed batch.
    pub(crate) fn note_flush(&self, by_size: bool) {
        if by_size {
            self.flushes_by_size.fetch_add(1, Ordering::Relaxed);
        } else {
            self.flushes_by_timeout.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fold a momentary in-flight round count into the peak gauge.
    pub(crate) fn note_inflight(&self, depth: u64) {
        self.peak_inflight.fetch_max(depth, Ordering::Relaxed);
    }

    /// Install (or clear) the retry/deadline policy for every
    /// subsequent backend execute. Takes `&self` so the policy can be
    /// set on a shared `Arc<Engine>` after labs and fleets have been
    /// built around it.
    pub fn set_retry_policy(&self, policy: Option<RetryPolicy>) {
        *self.retry.write().expect("retry policy") = policy;
    }

    /// The currently installed retry/deadline policy.
    pub fn retry_policy(&self) -> Option<RetryPolicy> {
        *self.retry.read().expect("retry policy")
    }

    /// Evaluate `configs` (each a padded `[f32; D_PAD]` unit vector) for
    /// one SUT surface under workload features `w` and deployment
    /// features `e`. Any `configs.len() >= 1` is accepted.
    ///
    /// One-shot convenience wrapper around [`Engine::prepare`] +
    /// [`Engine::evaluate_prepared`]; repeated callers (the manipulator,
    /// the benches) should prepare once — the §Perf pass showed the
    /// per-call upload of the constant parameter blocks (~150 KiB)
    /// dominating small-batch latency on the PJRT backend.
    pub fn evaluate(
        &self,
        params: &SurfaceParams,
        w: &[f32],
        e: &[f32],
        configs: &[Vec<f32>],
    ) -> Result<Vec<Perf>> {
        let prepared = self.prepare(params, w, e)?;
        self.evaluate_prepared(&prepared, configs)
    }

    /// Validate and hand one binding to the backend: device uploads on
    /// PJRT, workload/deployment premix on native. The returned
    /// [`PreparedCall`] is reusable for any number of
    /// [`Engine::evaluate_prepared`] calls against this engine.
    pub fn prepare(&self, params: &SurfaceParams, w: &[f32], e: &[f32]) -> Result<PreparedCall> {
        if w.len() != W_DIM || e.len() != E_DIM {
            return Err(ActsError::InvalidArg(format!(
                "w has {} (want {W_DIM}), e has {} (want {E_DIM})",
                w.len(),
                e.len()
            )));
        }
        params.validate()?;
        Ok(PreparedCall { data: Arc::from(self.backend.prepare(params, w, e)?) })
    }

    /// As [`Engine::prepare`], but content-cached: equal (params, w, e)
    /// bindings (bit-compared) share one backend-resident constant set.
    /// Besides skipping the re-upload/re-premix per deployment, the
    /// shared `Arc` gives same-binding callers *pointer-identical*
    /// prepared constants — the coalescing key of
    /// [`Engine::evaluate_coalesced`].
    pub fn prepare_cached(
        &self,
        params: &SurfaceParams,
        w: &[f32],
        e: &[f32],
    ) -> Result<Arc<PreparedCall>> {
        let mut key: Vec<u32> = Vec::with_capacity(W_DIM + E_DIM + 64);
        key.extend(w.iter().map(|x| x.to_bits()));
        key.extend(e.iter().map(|x| x.to_bits()));
        for (_, slice) in params.fields() {
            key.extend(slice.iter().map(|x| x.to_bits()));
        }
        if let Some(hit) = self.prepare_cache.lock().expect("prepare cache").get(&key) {
            return Ok(hit.clone());
        }
        // prepare outside the lock (it blocks on device uploads); a
        // concurrent racer keeps whichever entry landed first so every
        // caller still ends up pointer-identical
        let fresh = Arc::new(self.prepare(params, w, e)?);
        let mut cache = self.prepare_cache.lock().expect("prepare cache");
        Ok(cache.entry(key).or_insert(fresh).clone())
    }

    /// Evaluate against a prepared constant set. Only the config batch
    /// is handed to the backend per call.
    ///
    /// On the PJRT backend the batch is split greedily across the
    /// compiled buckets ([`shapes::plan_buckets`]); the native backend
    /// evaluates it as one call with no padding.
    pub fn evaluate_prepared(
        &self,
        prepared: &PreparedCall,
        configs: &[Vec<f32>],
    ) -> Result<Vec<Perf>> {
        if configs.is_empty() {
            return Ok(Vec::new());
        }
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rows_requested.fetch_add(configs.len() as u64, Ordering::Relaxed);
        let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
        self.evaluate_rows(prepared, &rows)
    }

    /// Serve many logical requests as shared executes: requests against
    /// the *same* [`PreparedCall`] object are concatenated (in request
    /// order) and executed together, then the results are split back
    /// per request by row range. Returns one `Vec<Perf>` per request,
    /// in request order.
    ///
    /// This is the cross-session batching primitive: 8 tuning sessions
    /// staging 32 rows each against one shared binding execute as a
    /// single 256-row call instead of eight partial-width calls.
    /// Requests against distinct prepared sets (different SUT surfaces,
    /// workloads or deployments) stay separate executes — per-call
    /// constants cannot mix — but still share this one entry point.
    pub fn evaluate_coalesced(&self, requests: &[EvalRequest<'_>]) -> Result<Vec<Vec<Perf>>> {
        self.requests.fetch_add(requests.len() as u64, Ordering::Relaxed);
        let requested: u64 = requests.iter().map(|r| r.configs.len() as u64).sum();
        self.rows_requested.fetch_add(requested, Ordering::Relaxed);
        let keys: Vec<usize> =
            requests.iter().map(|r| r.prepared as *const PreparedCall as usize).collect();
        let mut out: Vec<Vec<Perf>> = requests.iter().map(|_| Vec::new()).collect();
        for group in group_by_key(&keys) {
            let rows: Vec<&[f32]> = group
                .iter()
                .flat_map(|&i| requests[i].configs.iter().map(|c| c.as_slice()))
                .collect();
            if rows.is_empty() {
                continue;
            }
            let perfs = self.evaluate_rows(requests[group[0]].prepared, &rows)?;
            let mut offset = 0usize;
            for &i in &group {
                let n = requests[i].configs.len();
                out[i] = perfs[offset..offset + n].to_vec();
                offset += n;
            }
            debug_assert_eq!(offset, rows.len(), "demux must consume every row");
        }
        Ok(out)
    }

    /// As [`Engine::evaluate_coalesced`], but *overlapped*: every
    /// prepared-group is submitted through the backend's async path
    /// ([`ExecBackend::submit`]) before any output is synced, so a
    /// backend whose dispatch is async underneath (PJRT) has all the
    /// groups' executes in flight at once and pays one output sync per
    /// group instead of serialising dispatch behind sync. Results,
    /// telemetry accounting and retry semantics are identical to the
    /// synchronous path — for backends whose default `submit` wraps
    /// `execute`, this *is* the synchronous path, group by group.
    ///
    /// A [`RetryPolicy`] retries a failed group synchronously after its
    /// `wait` (same attempt counting, backoff and jitter schedule as
    /// [`Engine::execute_with_policy`]). A policy with a `deadline`
    /// falls back to the synchronous path wholesale: the deadline's
    /// helper-thread bound is incompatible with deferred sync.
    pub fn evaluate_coalesced_overlapped(
        &self,
        requests: &[EvalRequest<'_>],
    ) -> Result<Vec<Vec<Perf>>> {
        let policy = self.retry_policy();
        if policy.is_some_and(|p| p.deadline.is_some()) {
            return self.evaluate_coalesced(requests);
        }
        self.requests.fetch_add(requests.len() as u64, Ordering::Relaxed);
        let requested: u64 = requests.iter().map(|r| r.configs.len() as u64).sum();
        self.rows_requested.fetch_add(requested, Ordering::Relaxed);
        let keys: Vec<usize> =
            requests.iter().map(|r| r.prepared as *const PreparedCall as usize).collect();
        let mut out: Vec<Vec<Perf>> = requests.iter().map(|_| Vec::new()).collect();
        // phase 1: validate and submit every non-empty group
        let mut in_flight: Vec<(Vec<usize>, Result<Box<dyn PendingExecution + '_>>)> = Vec::new();
        for group in group_by_key(&keys) {
            let rows: Vec<&[f32]> = group
                .iter()
                .flat_map(|&i| requests[i].configs.iter().map(|c| c.as_slice()))
                .collect();
            if rows.is_empty() {
                continue;
            }
            for (i, r) in rows.iter().enumerate() {
                if r.len() != D_PAD {
                    return Err(ActsError::InvalidArg(format!(
                        "config {i} has {} lanes, want {D_PAD}",
                        r.len()
                    )));
                }
            }
            self.attempts.fetch_add(1, Ordering::Relaxed);
            let pending = self.backend.submit(requests[group[0]].prepared.data(), &rows);
            in_flight.push((group, pending));
        }
        // phase 2: sync outputs in submission order; a failed group
        // retries synchronously (the overlap is already spent)
        for (group, pending) in in_flight {
            let first = pending.and_then(|p| p.wait());
            let execution = match first {
                Ok(execution) => execution,
                Err(err) => self.retry_group(&group, requests, policy, err)?,
            };
            let rows_n: usize = group.iter().map(|&i| requests[i].configs.len()).sum();
            debug_assert_eq!(execution.perfs.len(), rows_n, "backend must answer every row");
            self.calls.fetch_add(execution.execute_calls, Ordering::Relaxed);
            self.rows.fetch_add(execution.rows_executed, Ordering::Relaxed);
            let mut offset = 0usize;
            for &i in &group {
                let n = requests[i].configs.len();
                out[i] = execution.perfs[offset..offset + n].to_vec();
                offset += n;
            }
        }
        Ok(out)
    }

    /// Synchronous retry tail for one overlapped group whose first
    /// (submitted) attempt failed: replays the remaining attempts on
    /// the exact [`Engine::execute_with_policy`] schedule — same
    /// attempt/retry counting, same seeded backoff jitter.
    fn retry_group(
        &self,
        group: &[usize],
        requests: &[EvalRequest<'_>],
        policy: Option<RetryPolicy>,
        first_err: ActsError,
    ) -> Result<Execution> {
        let Some(policy) = policy else { return Err(first_err) };
        let mut backoff = policy.base_backoff.min(policy.max_backoff);
        let mut last_err = first_err;
        for attempt in 1..policy.max_attempts.max(1) {
            if !backoff.is_zero() {
                let mut rng = Rng64::new(
                    policy.jitter_seed ^ ((attempt - 1) as u64).wrapping_mul(0x9E3779B97F4A7C15),
                );
                std::thread::sleep(backoff.mul_f64(1.0 + 0.5 * rng.f64()));
                backoff = (backoff * 2).min(policy.max_backoff);
            }
            self.attempts.fetch_add(1, Ordering::Relaxed);
            self.retries.fetch_add(1, Ordering::Relaxed);
            let rows: Vec<&[f32]> = group
                .iter()
                .flat_map(|&i| requests[i].configs.iter().map(|c| c.as_slice()))
                .collect();
            match self.backend.execute(requests[group[0]].prepared.data(), &rows) {
                Ok(execution) => return Ok(execution),
                Err(err) => last_err = err,
            }
        }
        Err(last_err)
    }

    /// Shared core of the evaluate paths: validate, hand to the
    /// backend, fold the physical cost into the telemetry.
    fn evaluate_rows(&self, prepared: &PreparedCall, rows: &[&[f32]]) -> Result<Vec<Perf>> {
        for (i, r) in rows.iter().enumerate() {
            if r.len() != D_PAD {
                return Err(ActsError::InvalidArg(format!(
                    "config {i} has {} lanes, want {D_PAD}",
                    r.len()
                )));
            }
        }
        let execution = match self.retry_policy() {
            None => {
                self.attempts.fetch_add(1, Ordering::Relaxed);
                self.backend.execute(prepared.data(), rows)?
            }
            Some(policy) => self.execute_with_policy(prepared, rows, &policy)?,
        };
        debug_assert_eq!(execution.perfs.len(), rows.len(), "backend must answer every row");
        self.calls.fetch_add(execution.execute_calls, Ordering::Relaxed);
        self.rows.fetch_add(execution.rows_executed, Ordering::Relaxed);
        Ok(execution.perfs)
    }

    /// Drive one backend execute under a [`RetryPolicy`]: up to
    /// `max_attempts` tries, exponential backoff with deterministic
    /// seeded jitter between them, the per-attempt deadline applied to
    /// each try. Only `Err` engages the machinery — a clean first
    /// attempt is indistinguishable from the policy-less path.
    fn execute_with_policy(
        &self,
        prepared: &PreparedCall,
        rows: &[&[f32]],
        policy: &RetryPolicy,
    ) -> Result<Execution> {
        let max_attempts = policy.max_attempts.max(1);
        let mut backoff = policy.base_backoff.min(policy.max_backoff);
        let mut last_err = None;
        for attempt in 0..max_attempts {
            self.attempts.fetch_add(1, Ordering::Relaxed);
            if attempt > 0 {
                self.retries.fetch_add(1, Ordering::Relaxed);
            }
            match self.execute_once(prepared, rows, policy.deadline) {
                Ok(execution) => return Ok(execution),
                Err(err) => last_err = Some(err),
            }
            if attempt + 1 < max_attempts && !backoff.is_zero() {
                // jitter is seeded per attempt ordinal, not from any
                // global counter, so the schedule never depends on how
                // threads interleave
                let mut rng = Rng64::new(
                    policy.jitter_seed ^ (attempt as u64).wrapping_mul(0x9E3779B97F4A7C15),
                );
                std::thread::sleep(backoff.mul_f64(1.0 + 0.5 * rng.f64()));
                backoff = (backoff * 2).min(policy.max_backoff);
            }
        }
        Err(last_err.expect("at least one attempt ran"))
    }

    /// Most deadline-killed helper threads that may run concurrently
    /// before the engine refuses to spawn more. A hung backend that
    /// eats every deadline would otherwise accumulate one live thread
    /// per killed attempt; at the cap the attempt fails fast (a
    /// retryable error naming the cap) instead of stacking another.
    const MAX_LIVE_ORPHANS: usize = 8;

    /// One attempt, optionally bounded by a wall-clock deadline. With a
    /// deadline the backend runs on a helper thread holding only `Arc`
    /// handles; on timeout the attempt fails (counted in
    /// `deadline_kills`) and the thread is *orphaned* — registered, not
    /// leaked: finished orphans are reaped (joined) before the next
    /// deadline spawn, the live count is capped at
    /// [`Engine::MAX_LIVE_ORPHANS`] and reported as
    /// [`EngineStats::live_orphans`]. The calling lane moves on either
    /// way.
    fn execute_once(
        &self,
        prepared: &PreparedCall,
        rows: &[&[f32]],
        deadline: Option<Duration>,
    ) -> Result<Execution> {
        let Some(deadline) = deadline else {
            return self.backend.execute(prepared.data(), rows);
        };
        {
            // reap finished orphans, then enforce the live cap
            let mut orphans = self.orphans.lock().expect("orphan registry");
            let mut i = 0;
            while i < orphans.len() {
                if orphans[i].is_finished() {
                    let _ = orphans.swap_remove(i).join();
                } else {
                    i += 1;
                }
            }
            if orphans.len() >= Self::MAX_LIVE_ORPHANS {
                return Err(ActsError::Xla(format!(
                    "deadline-kill orphan cap reached ({} live orphaned executes); \
                     refusing to spawn another helper thread",
                    Self::MAX_LIVE_ORPHANS
                )));
            }
        }
        let backend = Arc::clone(&self.backend);
        let data = prepared.data_arc();
        let owned: Vec<Vec<f32>> = rows.iter().map(|r| r.to_vec()).collect();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::Builder::new()
            .name("acts-deadline-exec".into())
            .spawn(move || {
                let rows: Vec<&[f32]> = owned.iter().map(|r| r.as_slice()).collect();
                let _ = tx.send(backend.execute(data.as_ref(), &rows));
            })
            .map_err(|e| ActsError::Xla(format!("could not spawn deadline helper: {e}")))?;
        match rx.recv_timeout(deadline) {
            Ok(result) => {
                // the helper already sent its answer; joining is instant
                let _ = handle.join();
                result
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                self.deadline_kills.fetch_add(1, Ordering::Relaxed);
                self.orphans.lock().expect("orphan registry").push(handle);
                Err(ActsError::Xla(format!(
                    "execute exceeded its {}ms deadline",
                    deadline.as_millis()
                )))
            }
            // the helper died without answering (it panicked): surface
            // that as a failed attempt rather than unwinding the lane
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                let _ = handle.join();
                Err(ActsError::Xla("execute thread died before answering".into()))
            }
        }
    }
}

/// Stable grouping of equal keys preserving first-appearance order —
/// the request-coalescing kernel of [`Engine::evaluate_coalesced`],
/// also reused by the scheduler to group requests per engine. Returns,
/// for each distinct key in first-seen order, the indices that carry
/// it (each group ascending).
pub(crate) fn group_by_key(keys: &[usize]) -> Vec<Vec<usize>> {
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
    for (i, &k) in keys.iter().enumerate() {
        match groups.iter_mut().find(|(gk, _)| *gk == k) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((k, vec![i])),
        }
    }
    groups.into_iter().map(|(_, idxs)| idxs).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_params_validate() {
        SurfaceParams::zeros().validate().unwrap();
    }

    #[test]
    fn validate_catches_wrong_length() {
        let mut p = SurfaceParams::zeros();
        p.qs.pop();
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("qs"), "{err}");
    }

    #[test]
    fn fields_cover_every_non_call_input() {
        let p = SurfaceParams::zeros();
        let mut idxs: Vec<usize> = p.fields().iter().map(|(i, _)| *i).collect();
        idxs.sort_unstable();
        assert_eq!(idxs, (3..20).collect::<Vec<_>>());
    }

    /// Compile-time guarantee behind parallel-session experiments and
    /// the pipelined scheduler: the engine and its prepared constants
    /// cross thread boundaries (now by construction — the backend trait
    /// requires `Send + Sync`, so no `unsafe` is needed at this layer).
    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Engine>();
        assert_send_sync::<PreparedCall>();
    }

    #[test]
    fn group_by_key_preserves_order_and_coalesces() {
        // three bindings interleaved: groups appear in first-seen order,
        // indices ascend within each group
        assert_eq!(
            group_by_key(&[7, 9, 7, 7, 3, 9]),
            vec![vec![0, 2, 3], vec![1, 5], vec![4]]
        );
        assert_eq!(group_by_key(&[]), Vec::<Vec<usize>>::new());
        assert_eq!(group_by_key(&[1]), vec![vec![0]]);
        // all distinct: one singleton group per request
        assert_eq!(group_by_key(&[4, 5, 6]), vec![vec![0], vec![1], vec![2]]);
    }

    // --- engine front-end over the native backend -------------------
    // (PJRT execution, including its bucket plans, is covered by the
    // `runtime_golden` integration test when artifacts exist on disk;
    // everything below runs anywhere.)

    fn native_engine() -> Engine {
        Engine::native().expect("native engine")
    }

    #[test]
    fn native_engine_reports_its_backend() {
        let engine = native_engine();
        assert_eq!(engine.backend_name(), "native");
        assert!(engine.platform().contains("native"), "{}", engine.platform());
    }

    #[test]
    fn empty_request_is_empty_and_uncounted() {
        let engine = native_engine();
        let (_, w, e, params) = crate::runtime::golden::pattern_call(1);
        let got = engine.evaluate(&params, &w, &e, &[]).unwrap();
        assert!(got.is_empty());
        assert_eq!(engine.stats().requests, 0);
    }

    #[test]
    fn invalid_inputs_are_rejected() {
        let engine = native_engine();
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(1);
        // wrong workload width
        assert!(engine.evaluate(&params, &w[..4], &e, &configs).is_err());
        // wrong config width
        let bad = vec![vec![0.5f32; 3]];
        assert!(engine.evaluate(&params, &w, &e, &bad).is_err());
    }

    #[test]
    fn prepare_cached_shares_identical_bindings() {
        let engine = native_engine();
        let (_, w, e, params) = crate::runtime::golden::pattern_call(1);
        let a = engine.prepare_cached(&params, &w, &e).unwrap();
        let b = engine.prepare_cached(&params, &w, &e).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "equal bindings must share one prepared set");
        let mut w2 = w.clone();
        w2[1] += 1.0;
        let c = engine.prepare_cached(&params, &w2, &e).unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "different bindings must not share");
    }

    #[test]
    fn coalesced_requests_match_separate_evaluation_bitwise() {
        let engine = native_engine();
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(16);
        let prepared = engine.prepare_cached(&params, &w, &e).unwrap();
        // a second binding (different w) that must NOT coalesce
        let mut w2 = w.clone();
        w2[0] += 0.25;
        let prepared2 = engine.prepare_cached(&params, &w2, &e).unwrap();

        let separate_a = engine.evaluate_prepared(&prepared, &configs).unwrap();
        let separate_b = engine.evaluate_prepared(&prepared, &configs[..7]).unwrap();
        let separate_c = engine.evaluate_prepared(&prepared2, &configs[..5]).unwrap();

        let s0 = engine.stats();
        let out = engine
            .evaluate_coalesced(&[
                EvalRequest { prepared: &prepared, configs: &configs },
                EvalRequest { prepared: &prepared, configs: &configs[..7] },
                EvalRequest { prepared: &prepared2, configs: &configs[..5] },
            ])
            .unwrap();
        let s1 = engine.stats();
        assert_eq!(out.len(), 3);
        // native rows are batch-size invariant, so coalescing is exact
        assert_eq!(out[0], separate_a);
        assert_eq!(out[1], separate_b);
        assert_eq!(out[2], separate_c);
        assert_eq!(s1.requests - s0.requests, 3);
        assert_eq!(s1.rows_requested - s0.rows_requested, 28);
        // two same-binding requests share one execute; the third gets
        // its own; native never pads
        assert_eq!(s1.execute_calls - s0.execute_calls, 2);
        assert_eq!(s1.rows_executed - s0.rows_executed, 28);
    }

    // --- retry/deadline policy --------------------------------------

    use crate::runtime::chaos::{ChaosBackend, Fault, FaultPlan};
    use crate::runtime::native::NativeBackend;

    fn chaos_engine(plan: FaultPlan) -> Engine {
        let native = NativeBackend::new().expect("native backend");
        Engine::from_backend(Box::new(ChaosBackend::new(Box::new(native), plan)))
    }

    #[test]
    fn fault_free_retry_policy_is_bitwise_invisible() {
        let plain = native_engine();
        let retrying = native_engine();
        retrying.set_retry_policy(Some(RetryPolicy::default()));
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(16);
        let want = plain.evaluate(&params, &w, &e, &configs).unwrap();
        let got = retrying.evaluate(&params, &w, &e, &configs).unwrap();
        assert_eq!(want, got, "a fault-free retried run must stay bit-identical");
        let stats = retrying.stats();
        assert_eq!(stats.attempts, 1);
        assert_eq!(stats.retries, 0);
        assert_eq!(stats.deadline_kills, 0);
    }

    #[test]
    fn retry_policy_absorbs_a_transient_fault() {
        // pick a seed whose fault sequence starts Transient, then None:
        // the first attempt fails, the retry lands clean
        let seed = (0..u64::MAX)
            .find(|&s| {
                let p = FaultPlan::transient(s, 0.5);
                p.fault_for(0) == Fault::Transient && p.fault_for(1) == Fault::None
            })
            .unwrap();
        let engine = chaos_engine(FaultPlan::transient(seed, 0.5));
        engine.set_retry_policy(Some(RetryPolicy::default()));
        let clean = native_engine();
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(4);
        let want = clean.evaluate(&params, &w, &e, &configs).unwrap();
        let got = engine.evaluate(&params, &w, &e, &configs).unwrap();
        assert_eq!(want, got, "the retried result must match a clean run bitwise");
        let stats = engine.stats();
        assert_eq!(stats.attempts, 2);
        assert_eq!(stats.retries, 1);
    }

    #[test]
    fn retry_policy_gives_up_after_max_attempts() {
        let engine = chaos_engine(FaultPlan::transient(5, 1.0)); // every execute fails
        engine.set_retry_policy(Some(RetryPolicy { max_attempts: 3, ..RetryPolicy::default() }));
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(2);
        let err = engine.evaluate(&params, &w, &e, &configs).unwrap_err();
        assert!(err.to_string().contains("transient"), "{err}");
        let stats = engine.stats();
        assert_eq!(stats.attempts, 3);
        assert_eq!(stats.retries, 2);
    }

    #[test]
    fn deadline_kills_a_hung_execute_instead_of_wedging() {
        let plan = FaultPlan {
            hang_p: 1.0,
            hang: Duration::from_secs(2),
            ..FaultPlan::seeded(8)
        };
        let engine = chaos_engine(plan);
        engine.set_retry_policy(Some(RetryPolicy {
            max_attempts: 1,
            deadline: Some(Duration::from_millis(50)),
            ..RetryPolicy::default()
        }));
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(2);
        let start = std::time::Instant::now();
        let err = engine.evaluate(&params, &w, &e, &configs).unwrap_err();
        assert!(start.elapsed() < Duration::from_secs(2), "deadline must not wait out the hang");
        assert!(err.to_string().contains("deadline"), "{err}");
        assert_eq!(engine.stats().deadline_kills, 1);
    }

    #[test]
    fn retry_policy_can_be_cleared() {
        let engine = native_engine();
        engine.set_retry_policy(Some(RetryPolicy::default()));
        assert!(engine.retry_policy().is_some());
        engine.set_retry_policy(None);
        assert!(engine.retry_policy().is_none());
    }

    // --- overlapped submission + streaming telemetry ----------------

    #[test]
    fn overlapped_coalescing_matches_the_synchronous_path_bitwise() {
        let engine = native_engine();
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(16);
        let prepared = engine.prepare_cached(&params, &w, &e).unwrap();
        let mut w2 = w.clone();
        w2[0] += 0.25;
        let prepared2 = engine.prepare_cached(&params, &w2, &e).unwrap();
        let empty: Vec<Vec<f32>> = Vec::new();
        let reqs = [
            EvalRequest { prepared: &prepared, configs: &configs },
            EvalRequest { prepared: &prepared, configs: &configs[..7] },
            EvalRequest { prepared: &prepared2, configs: &configs[..5] },
            EvalRequest { prepared: &prepared2, configs: &empty },
        ];
        let sync = engine.evaluate_coalesced(&reqs).unwrap();
        let s0 = engine.stats();
        let overlapped = engine.evaluate_coalesced_overlapped(&reqs).unwrap();
        let s1 = engine.stats();
        assert_eq!(sync, overlapped, "overlap must not change any per-row result");
        // same funnel accounting as the synchronous path
        assert_eq!(s1.requests - s0.requests, 4);
        assert_eq!(s1.rows_requested - s0.rows_requested, 28);
        assert_eq!(s1.execute_calls - s0.execute_calls, 2);
        assert_eq!(s1.rows_executed - s0.rows_executed, 28);
        assert_eq!(s1.attempts - s0.attempts, 2);
    }

    #[test]
    fn overlapped_retry_absorbs_a_transient_fault_on_the_same_schedule() {
        let seed = (0..u64::MAX)
            .find(|&s| {
                let p = FaultPlan::transient(s, 0.5);
                p.fault_for(0) == Fault::Transient && p.fault_for(1) == Fault::None
            })
            .unwrap();
        let engine = chaos_engine(FaultPlan::transient(seed, 0.5));
        engine.set_retry_policy(Some(RetryPolicy::default()));
        let clean = native_engine();
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(4);
        let want = clean.evaluate(&params, &w, &e, &configs).unwrap();
        let prepared = engine.prepare_cached(&params, &w, &e).unwrap();
        let reqs = [EvalRequest { prepared: &prepared, configs: &configs }];
        let got = engine.evaluate_coalesced_overlapped(&reqs).unwrap();
        assert_eq!(got[0], want, "the retried overlapped result must match a clean run bitwise");
        let stats = engine.stats();
        assert_eq!(stats.attempts, 2);
        assert_eq!(stats.retries, 1);
    }

    #[test]
    fn overlapped_path_with_a_deadline_falls_back_to_synchronous() {
        let plan = FaultPlan {
            hang_p: 1.0,
            hang: Duration::from_secs(2),
            ..FaultPlan::seeded(8)
        };
        let engine = chaos_engine(plan);
        engine.set_retry_policy(Some(RetryPolicy {
            max_attempts: 1,
            deadline: Some(Duration::from_millis(50)),
            ..RetryPolicy::default()
        }));
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(2);
        let prepared = engine.prepare_cached(&params, &w, &e).unwrap();
        let start = std::time::Instant::now();
        let reqs = [EvalRequest { prepared: &prepared, configs: &configs }];
        let err = engine.evaluate_coalesced_overlapped(&reqs).unwrap_err();
        assert!(start.elapsed() < Duration::from_secs(2), "the deadline must still apply");
        assert!(err.to_string().contains("deadline"), "{err}");
        assert_eq!(engine.stats().deadline_kills, 1);
    }

    #[test]
    fn flush_and_inflight_telemetry_lands_in_stats() {
        let engine = native_engine();
        engine.note_flush(true);
        engine.note_flush(false);
        engine.note_flush(false);
        engine.note_inflight(3);
        engine.note_inflight(7);
        engine.note_inflight(2);
        let s = engine.stats();
        assert_eq!(s.flushes_by_size, 1);
        assert_eq!(s.flushes_by_timeout, 2);
        assert_eq!(s.peak_inflight, 7, "the gauge keeps the high-water mark");
    }

    // --- orphan accounting for deadline-killed executes -------------

    #[test]
    fn deadline_kill_orphans_are_counted_then_reaped() {
        let plan = FaultPlan {
            hang_p: 1.0,
            hang: Duration::from_millis(300),
            ..FaultPlan::seeded(8)
        };
        let engine = chaos_engine(plan);
        engine.set_retry_policy(Some(RetryPolicy {
            max_attempts: 1,
            deadline: Some(Duration::from_millis(30)),
            ..RetryPolicy::default()
        }));
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(2);
        assert!(engine.evaluate(&params, &w, &e, &configs).is_err());
        let stats = engine.stats();
        assert_eq!(stats.deadline_kills, 1);
        assert_eq!(stats.live_orphans, 1, "the killed helper is still hung");
        // once the injected hang elapses the orphan finishes and the
        // live gauge drops — nothing leaks past the hang itself
        std::thread::sleep(Duration::from_millis(600));
        assert_eq!(engine.stats().live_orphans, 0);
    }

    #[test]
    fn orphan_cap_stops_runaway_deadline_spawns() {
        let plan = FaultPlan {
            hang_p: 1.0,
            hang: Duration::from_secs(2),
            ..FaultPlan::seeded(8)
        };
        let engine = chaos_engine(plan);
        engine.set_retry_policy(Some(RetryPolicy {
            max_attempts: 1,
            deadline: Some(Duration::from_millis(5)),
            ..RetryPolicy::default()
        }));
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(1);
        for i in 0..Engine::MAX_LIVE_ORPHANS {
            let err = engine.evaluate(&params, &w, &e, &configs).unwrap_err();
            assert!(err.to_string().contains("deadline"), "kill {i}: {err}");
        }
        assert_eq!(engine.stats().live_orphans, Engine::MAX_LIVE_ORPHANS as u64);
        // at the cap the next attempt fails fast instead of spawning
        let err = engine.evaluate(&params, &w, &e, &configs).unwrap_err();
        assert!(err.to_string().contains("orphan cap"), "{err}");
        assert_eq!(
            engine.stats().deadline_kills,
            Engine::MAX_LIVE_ORPHANS as u64,
            "the capped attempt never spawned a helper"
        );
    }
}
