//! SIMD dispatch for the native backend: an AVX2+FMA f32x8 row
//! evaluator with the scalar loop as the portable fallback.
//!
//! # Dispatch contract
//!
//! The path is resolved **once, at backend construction** — never per
//! batch — from a requested [`SimdMode`] (`ACTS_NATIVE_SIMD`, default
//! auto) plus runtime feature detection. A constructed backend
//! therefore evaluates every row of its lifetime on one fixed kernel,
//! which keeps per-row results exactly batch-size invariant and
//! run-to-run deterministic — the bitwise contract the scheduler's
//! coalescing / pipelining / streaming equivalence tests rely on.
//!
//! The two paths are each individually bitwise-stable but are **not**
//! bitwise-identical to each other: the vector kernel accumulates in a
//! different (fixed) order and evaluates `exp`/`sin` with polynomial
//! approximations (Cephes-style, ~1e-7 relative error) instead of libm.
//! Scalar and AVX2 agree to well within the golden-oracle tolerances
//! (property-tested at 1e-5 relative), and the chosen path is surfaced
//! through `platform()`, `EngineStats::simd_width` and the fleet JSON
//! so `acts fleet-diff` can attribute numeric drift to a dispatch
//! change.
//!
//! # Why AVX2+FMA and nothing else
//!
//! `D_PAD = 64` is exactly eight f32x8 lanes, so every per-row loop
//! (basis accumulation, the `u·q·uᵀ` interaction, RBF bump distances,
//! stacked cliff/gate projections) vectorizes with no remainder
//! handling. The kernel uses `core::arch` intrinsics behind
//! `is_x86_feature_detected!` — no new dependencies, and non-x86_64
//! hosts simply resolve to the scalar path.

use crate::error::{ActsError, Result};

/// Requested SIMD mode — the `ACTS_NATIVE_SIMD` spelling. Resolved
/// into a [`Dispatch`] exactly once, at backend construction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdMode {
    /// AVX2+FMA when the host supports it, scalar otherwise. Default.
    #[default]
    Auto,
    /// Require the AVX2 path; constructing a backend on a host without
    /// AVX2+FMA is an error — pinning must not silently change paths.
    Avx2,
    /// Force the portable scalar loop everywhere.
    Scalar,
}

impl SimdMode {
    /// Registry spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Avx2 => "avx2",
            SimdMode::Scalar => "scalar",
        }
    }
}

/// Parse an `ACTS_NATIVE_SIMD` spelling. Unit-testable without
/// mutating the process environment.
pub fn parse_native_simd(value: &str) -> Result<SimdMode> {
    match value.trim().to_ascii_lowercase().as_str() {
        "auto" => Ok(SimdMode::Auto),
        "avx2" => Ok(SimdMode::Avx2),
        "scalar" => Ok(SimdMode::Scalar),
        _ => Err(ActsError::InvalidArg(format!(
            "ACTS_NATIVE_SIMD=`{value}` is not a recognised SIMD mode \
             (accepted: auto, avx2, scalar)"
        ))),
    }
}

/// Resolve the `ACTS_NATIVE_SIMD` environment variable: `None` when
/// unset, a startup error when set to something unusable — a typo must
/// not silently run a different evaluator path.
pub fn native_simd_from_env() -> Result<Option<SimdMode>> {
    match std::env::var("ACTS_NATIVE_SIMD") {
        Ok(v) => parse_native_simd(&v).map(Some),
        Err(_) => Ok(None),
    }
}

/// The resolved row-evaluator path a backend was constructed with.
/// [`Dispatch::Avx2`] is only ever constructed through [`resolve`] on
/// a host where [`avx2_available`] returned true.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// The portable scalar loop.
    Scalar,
    /// The AVX2+FMA f32x8 kernel.
    Avx2,
}

impl Dispatch {
    /// Diagnostic spelling (`platform()`, fleet JSON, bench dump).
    pub fn as_str(&self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Avx2 => "avx2",
        }
    }

    /// f32 lanes the row evaluator processes per step (1 = scalar).
    pub fn lanes(&self) -> u64 {
        match self {
            Dispatch::Scalar => 1,
            Dispatch::Avx2 => 8,
        }
    }
}

/// Host support for the AVX2 path. FMA is required alongside AVX2: the
/// kernel is built from fused multiply-adds, and determinism demands
/// the fused path be decided up front, not left to codegen.
#[cfg(target_arch = "x86_64")]
pub fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// Host support for the AVX2 path (never, off x86_64).
#[cfg(not(target_arch = "x86_64"))]
pub fn avx2_available() -> bool {
    false
}

/// Resolve a requested mode into the construction-time dispatch.
/// `Auto` never fails; `Avx2` fails fast on hosts without AVX2+FMA.
pub fn resolve(mode: SimdMode) -> Result<Dispatch> {
    match mode {
        SimdMode::Scalar => Ok(Dispatch::Scalar),
        SimdMode::Auto => {
            if avx2_available() {
                Ok(Dispatch::Avx2)
            } else {
                Ok(Dispatch::Scalar)
            }
        }
        SimdMode::Avx2 => {
            if avx2_available() {
                Ok(Dispatch::Avx2)
            } else {
                Err(ActsError::InvalidArg(
                    "ACTS_NATIVE_SIMD=avx2 is pinned but this host has no AVX2+FMA \
                     (accepted here: auto, scalar)"
                        .into(),
                ))
            }
        }
    }
}

/// The AVX2+FMA row kernel. Everything here is gated to x86_64 at
/// compile time and to [`avx2_available`] hosts at construction time
/// (see [`resolve`]); [`eval_row`] is only reachable through a
/// [`Dispatch::Avx2`] backend.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::excessive_precision, clippy::approx_constant)]
pub(crate) mod avx2 {
    use super::super::engine::Perf;
    use super::super::native::{sigmoid, NativePrepared};
    use super::super::shapes::{D_PAD, G, J, R, RG};
    use core::arch::x86_64::*;

    /// f32x8 chunks per padded row.
    const NC: usize = D_PAD / 8;

    /// Horizontal sum with a fixed reduction tree (deterministic).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps::<1>(v);
        let lo = _mm256_castps256_ps128(v);
        let q = _mm_add_ps(lo, hi);
        let h = _mm_add_ps(q, _mm_movehl_ps(q, q));
        let s = _mm_add_ss(h, _mm_shuffle_ps::<1>(h, h));
        _mm_cvtss_f32(s)
    }

    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn neg(v: __m256) -> __m256 {
        _mm256_xor_ps(v, _mm256_set1_ps(-0.0))
    }

    /// Vectorized `exp` (Cephes `expf` polynomial, ~1e-7 relative).
    /// Inputs are clamped to ±87.3, far past every finite use here
    /// (sigmoid saturates, bump exponents are <= 0).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn exp_ps(x: __m256) -> __m256 {
        let one = _mm256_set1_ps(1.0);
        let x = _mm256_min_ps(x, _mm256_set1_ps(87.3));
        let x = _mm256_max_ps(x, _mm256_set1_ps(-87.3));
        // n = floor(x / ln2 + 1/2); r = x - n ln2 (split constant)
        let fx = _mm256_fmadd_ps(x, _mm256_set1_ps(std::f32::consts::LOG2_E), _mm256_set1_ps(0.5));
        let fx = _mm256_floor_ps(fx);
        let x = _mm256_fmadd_ps(fx, _mm256_set1_ps(-0.693359375), x);
        let x = _mm256_fmadd_ps(fx, _mm256_set1_ps(2.1219444e-4), x);
        let z = _mm256_mul_ps(x, x);
        let mut y = _mm256_set1_ps(1.9875691e-4);
        y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(1.3981999e-3));
        y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(8.3334519e-3));
        y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(4.1665795e-2));
        y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(1.6666665e-1));
        y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(5.0000001e-1));
        y = _mm256_fmadd_ps(y, z, x);
        y = _mm256_add_ps(y, one);
        // scale by 2^n through the exponent bits
        let n = _mm256_cvttps_epi32(fx);
        let n = _mm256_add_epi32(n, _mm256_set1_epi32(0x7f));
        let pow2n = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(n));
        _mm256_mul_ps(y, pow2n)
    }

    /// Vectorized `sin` (Cephes `sinf` with 4/pi range reduction,
    /// ~1e-7 absolute on the basis arguments `pi * u`, u in [0, 1]).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn sin_ps(x: __m256) -> __m256 {
        let sign_mask = _mm256_set1_ps(-0.0);
        let mut sign_bit = _mm256_and_ps(x, sign_mask);
        let x = _mm256_andnot_ps(sign_mask, x);
        // octant index j, rounded to the even reduction the sinf
        // algorithm wants
        let y = _mm256_mul_ps(x, _mm256_set1_ps(1.27323954)); // 4/pi
        let mut j = _mm256_cvttps_epi32(y);
        j = _mm256_add_epi32(j, _mm256_set1_epi32(1));
        j = _mm256_and_si256(j, _mm256_set1_epi32(!1));
        let y = _mm256_cvtepi32_ps(j);
        // octants 4..7 flip the sign; octants 2,3 use the cosine poly
        let swap_sign =
            _mm256_castsi256_ps(_mm256_slli_epi32::<29>(_mm256_and_si256(j, _mm256_set1_epi32(4))));
        let poly_mask = _mm256_castsi256_ps(_mm256_cmpeq_epi32(
            _mm256_and_si256(j, _mm256_set1_epi32(2)),
            _mm256_setzero_si256(),
        ));
        sign_bit = _mm256_xor_ps(sign_bit, swap_sign);
        // extended-precision modular reduction: x - j * pi/4 in three
        // steps (split constant)
        let x = _mm256_fmadd_ps(y, _mm256_set1_ps(-0.78515625), x);
        let x = _mm256_fmadd_ps(y, _mm256_set1_ps(-2.4187565e-4), x);
        let x = _mm256_fmadd_ps(y, _mm256_set1_ps(-3.7748950e-8), x);
        let z = _mm256_mul_ps(x, x);
        // cosine polynomial (octants 2, 3)
        let mut yc = _mm256_set1_ps(2.4433157e-5);
        yc = _mm256_fmadd_ps(yc, z, _mm256_set1_ps(-1.3887316e-3));
        yc = _mm256_fmadd_ps(yc, z, _mm256_set1_ps(4.1666646e-2));
        yc = _mm256_mul_ps(yc, _mm256_mul_ps(z, z));
        yc = _mm256_fmadd_ps(z, _mm256_set1_ps(-0.5), yc);
        yc = _mm256_add_ps(yc, _mm256_set1_ps(1.0));
        // sine polynomial (octants 0, 1)
        let mut ys = _mm256_set1_ps(-1.9515296e-4);
        ys = _mm256_fmadd_ps(ys, z, _mm256_set1_ps(8.3321609e-3));
        ys = _mm256_fmadd_ps(ys, z, _mm256_set1_ps(-1.6666655e-1));
        ys = _mm256_mul_ps(ys, _mm256_mul_ps(z, x));
        ys = _mm256_add_ps(ys, x);
        let y = _mm256_or_ps(_mm256_and_ps(poly_mask, ys), _mm256_andnot_ps(poly_mask, yc));
        _mm256_xor_ps(y, sign_bit)
    }

    /// Vectorized logistic sigmoid via [`exp_ps`].
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn sigmoid_ps(x: __m256) -> __m256 {
        let one = _mm256_set1_ps(1.0);
        _mm256_div_ps(one, _mm256_add_ps(one, exp_ps(neg(x))))
    }

    /// Evaluate one padded `[f32; D_PAD]` unit row — the f32x8 mirror
    /// of `NativePrepared::eval_row_scalar`, same blocks, fixed lane
    /// order.
    ///
    /// # Safety
    ///
    /// The caller must guarantee the host supports AVX2+FMA (enforced
    /// by constructing [`super::Dispatch::Avx2`] through
    /// [`super::resolve`]). The raw loads rely on `prepare` having
    /// built every block of `p` at its documented length; `u`'s width
    /// is asserted here.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(crate) unsafe fn eval_row(p: &NativePrepared, u: &[f32]) -> Perf {
        assert_eq!(u.len(), D_PAD, "padded row width");
        debug_assert_eq!(p.b_lin.len(), D_PAD);
        debug_assert_eq!(p.q.len(), D_PAD * D_PAD);
        debug_assert_eq!(p.centers.len(), J * D_PAD);
        debug_assert_eq!(p.dirs.len(), RG * D_PAD);
        let up = u.as_ptr();
        let mut uc = [_mm256_setzero_ps(); NC];
        for c in 0..NC {
            uc[c] = _mm256_loadu_ps(up.add(8 * c));
        }

        // base: per-knob basis response phi(u) . w, all four components
        // fused per chunk
        let pi = _mm256_set1_ps(std::f32::consts::PI);
        let mut acc = _mm256_setzero_ps();
        for c in 0..NC {
            let x = uc[c];
            acc = _mm256_fmadd_ps(x, _mm256_loadu_ps(p.b_lin.as_ptr().add(8 * c)), acc);
            let xx = _mm256_mul_ps(x, x);
            acc = _mm256_fmadd_ps(xx, _mm256_loadu_ps(p.b_quad.as_ptr().add(8 * c)), acc);
            let hump = sin_ps(_mm256_mul_ps(pi, x));
            acc = _mm256_fmadd_ps(hump, _mm256_loadu_ps(p.b_hump.as_ptr().add(8 * c)), acc);
            let s = _mm256_loadu_ps(p.step_s.as_ptr().add(8 * c));
            let t = _mm256_loadu_ps(p.step_t.as_ptr().add(8 * c));
            let step = sigmoid_ps(_mm256_mul_ps(s, _mm256_sub_ps(x, t)));
            acc = _mm256_fmadd_ps(step, _mm256_loadu_ps(p.b_step.as_ptr().add(8 * c)), acc);
        }
        let base = hsum(acc);

        // inter: u q u^T column-wise — accumulate v = u q as eight
        // vector lanes (no per-row horizontal sums), then dot with u
        let mut v = [_mm256_setzero_ps(); NC];
        for (k, &uk) in u.iter().enumerate() {
            let ukb = _mm256_set1_ps(uk);
            let qrow = p.q.as_ptr().add(k * D_PAD);
            for c in 0..NC {
                v[c] = _mm256_fmadd_ps(ukb, _mm256_loadu_ps(qrow.add(8 * c)), v[c]);
            }
        }
        let mut iacc = _mm256_setzero_ps();
        for c in 0..NC {
            iacc = _mm256_fmadd_ps(uc[c], v[c], iacc);
        }
        let inter = hsum(iacc);

        // bumps: squared distances via the expanded square, then the
        // J exponentials eight at a time
        let mut nacc = _mm256_setzero_ps();
        for &x in uc.iter() {
            nacc = _mm256_fmadd_ps(x, x, nacc);
        }
        let u_norm2 = hsum(nacc);
        let mut d2 = [0.0f32; J];
        for (j, slot) in d2.iter_mut().enumerate() {
            let cp = p.centers.as_ptr().add(j * D_PAD);
            let mut dacc = _mm256_setzero_ps();
            for c in 0..NC {
                dacc = _mm256_fmadd_ps(uc[c], _mm256_loadu_ps(cp.add(8 * c)), dacc);
            }
            *slot = u_norm2 + p.center_norm2[j] - 2.0 * hsum(dacc);
        }
        let mut bacc = _mm256_setzero_ps();
        for jb in 0..(J / 8) {
            let dd = _mm256_loadu_ps(d2.as_ptr().add(8 * jb));
            let ir = _mm256_loadu_ps(p.inv_rho2.as_ptr().add(8 * jb));
            let amp = _mm256_loadu_ps(p.amps.as_ptr().add(8 * jb));
            let ex = exp_ps(neg(_mm256_mul_ps(dd, ir)));
            bacc = _mm256_fmadd_ps(amp, ex, bacc);
        }
        let bumps = hsum(bacc);

        // stacked cliff + gate direction projections
        let mut proj = [0.0f32; RG];
        for (r, slot) in proj.iter_mut().enumerate() {
            let dp = p.dirs.as_ptr().add(r * D_PAD);
            let mut pacc = _mm256_setzero_ps();
            for c in 0..NC {
                pacc = _mm256_fmadd_ps(uc[c], _mm256_loadu_ps(dp.add(8 * c)), pacc);
            }
            *slot = hsum(pacc);
        }
        // cliffs: R = 8 is exactly one vector of sigmoids
        let pv = _mm256_loadu_ps(proj.as_ptr());
        let tau = _mm256_loadu_ps(p.cliff_tau.as_ptr());
        let kappa = _mm256_loadu_ps(p.cliff_kappa.as_ptr());
        let gain = _mm256_loadu_ps(p.cliff_gain.as_ptr());
        let sig = sigmoid_ps(_mm256_mul_ps(kappa, _mm256_sub_ps(pv, tau)));
        let cliffs = hsum(_mm256_mul_ps(gain, sig));
        // gate: G = 4 scalar factors — too narrow to vectorize, and the
        // libm tail keeps this block bitwise-equal to the scalar path
        let mut gate = 1.0f32;
        for g in 0..G {
            let floor = p.gate_floor[g];
            gate *= floor
                + (1.0 - floor) * sigmoid(p.gate_kappa[g] * (proj[R + g] - p.gate_tau[g]));
        }

        p.heads(base + inter + bumps + cliffs, gate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simd_spellings_parse_or_name_the_variable() {
        assert_eq!(parse_native_simd("auto").unwrap(), SimdMode::Auto);
        assert_eq!(parse_native_simd(" AVX2 ").unwrap(), SimdMode::Avx2);
        assert_eq!(parse_native_simd("scalar").unwrap(), SimdMode::Scalar);
        for bad in ["avx512", "sse", "", "fast", "1"] {
            let err = parse_native_simd(bad).unwrap_err().to_string();
            assert!(err.contains("ACTS_NATIVE_SIMD"), "{bad}: {err}");
            assert!(err.contains("auto, avx2, scalar"), "{bad}: {err}");
        }
    }

    #[test]
    fn mode_spellings_round_trip() {
        for mode in [SimdMode::Auto, SimdMode::Avx2, SimdMode::Scalar] {
            assert_eq!(parse_native_simd(mode.as_str()).unwrap(), mode);
        }
        assert_eq!(SimdMode::default(), SimdMode::Auto);
    }

    #[test]
    fn resolution_is_total_for_auto_and_scalar_and_honest_for_avx2() {
        assert_eq!(resolve(SimdMode::Scalar).unwrap(), Dispatch::Scalar);
        let auto = resolve(SimdMode::Auto).unwrap();
        if avx2_available() {
            assert_eq!(auto, Dispatch::Avx2);
            assert_eq!(resolve(SimdMode::Avx2).unwrap(), Dispatch::Avx2);
        } else {
            assert_eq!(auto, Dispatch::Scalar);
            let err = resolve(SimdMode::Avx2).unwrap_err().to_string();
            assert!(err.contains("AVX2"), "{err}");
        }
    }

    #[test]
    fn dispatch_lanes_and_spellings() {
        assert_eq!(Dispatch::Scalar.lanes(), 1);
        assert_eq!(Dispatch::Avx2.lanes(), 8);
        assert_eq!(Dispatch::Scalar.as_str(), "scalar");
        assert_eq!(Dispatch::Avx2.as_str(), "avx2");
    }

    /// The vector kernel against the scalar loop on the golden
    /// patterned inputs (the broad randomized property test lives in
    /// the conformance integration suite).
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_kernel_matches_scalar_on_pattern_inputs() {
        use crate::runtime::backend::ExecBackend;
        use crate::runtime::native::NativeBackend;
        if !avx2_available() {
            eprintln!("SKIP avx2_kernel_matches_scalar: host has no AVX2+FMA");
            return;
        }
        let scalar = NativeBackend::with_options(1, SimdMode::Scalar).unwrap();
        let vector = NativeBackend::with_options(1, SimdMode::Avx2).unwrap();
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(16);
        let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
        let ps = scalar.prepare(&params, &w, &e).unwrap();
        let pv = vector.prepare(&params, &w, &e).unwrap();
        let a = scalar.execute(ps.as_ref(), &rows).unwrap();
        let b = vector.execute(pv.as_ref(), &rows).unwrap();
        for (i, (x, y)) in a.perfs.iter().zip(&b.perfs).enumerate() {
            let ttol = 1e-5 * (1.0 + x.throughput.abs());
            let ltol = 1e-5 * (1.0 + x.latency.abs());
            assert!(
                (x.throughput - y.throughput).abs() < ttol,
                "row {i}: scalar thr {} vs avx2 {}",
                x.throughput,
                y.throughput
            );
            assert!(
                (x.latency - y.latency).abs() < ltol,
                "row {i}: scalar lat {} vs avx2 {}",
                x.latency,
                y.latency
            );
        }
    }
}
