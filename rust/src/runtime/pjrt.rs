//! The PJRT execution backend: compile-once, execute-many surface
//! artifacts with static batch buckets.
//!
//! One [`PjrtBackend`] owns a PJRT CPU client and a compiled executable
//! per static batch bucket (1 / 16 / 256 / 2048). An execute of `B`
//! rows is decomposed greedily across the buckets
//! ([`super::shapes::plan_buckets`]): exact chunks of the largest
//! fitting bucket plus at most one padded call for the remainder, so an
//! odd batch never executes a whole wide bucket of padding.
//!
//! Everything backend-independent (validation, coalescing, caching,
//! telemetry) lives in [`super::engine::Engine`]; this module is purely
//! the XLA-facing half behind [`super::backend::ExecBackend`].

use super::backend::{ExecBackend, Execution, PendingExecution, PreparedData};
use super::engine::{Perf, SurfaceParams};
use super::shapes::{self, BUCKETS, D_PAD};
use crate::error::{ActsError, Result};
use std::any::Any;
use std::path::{Path, PathBuf};

/// Compile-once PJRT backend (see the module docs).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    /// (bucket, executable), ascending bucket order.
    execs: Vec<(usize, xla::PjRtLoadedExecutable)>,
    artifacts_dir: PathBuf,
}

// SAFETY: two obligations are being claimed here (re-audited for the
// multi-threaded scheduler pipeline, whose worker thread executes on
// this backend while the scheduler thread stages and may concurrently
// `prepare` through the same `&self`).
// (1) PJRT side: the PJRT C API requires clients, loaded executables
//     and buffers to be usable from any thread concurrently (the CPU
//     client serialises internally where it must), and every method
//     here takes `&self` with no interior mutability at all — the
//     telemetry counters and the prepared-constant cache both live in
//     the engine front-end, not here.
// (2) Wrapper side: the `xla` binding must hold plain FFI handles for
//     the client/executable/buffer/device types — no thread-unsafe
//     shared ownership such as `Rc` refcounts cloned per call. This is
//     the part the compiler cannot see past and it MUST be re-audited
//     whenever the binding is vendored or upgraded:
//     * the in-repo `vendor/xla` STUB (audited 2026-07): `PjRtClient`,
//       `PjRtLoadedExecutable`, `PjRtBuffer` and `PjRtDevice` are
//       uninhabited enums — no value of these types can exist, so the
//       claim is vacuously true there (the compiler would even derive
//       the auto traits itself); `Literal` is `Vec<f32>` + `Vec<i64>`,
//       plainly `Send + Sync`.
//     * a REAL binding must be checked for `Rc`/`RefCell`/thread-local
//       state behind those four types before swapping the path entry
//       in Cargo.toml (the rust bindings around `xla_extension` keep
//       raw `*mut` handles — fine — but verify the exact revision).
//     Per-call wrapper objects (literals, buffers) are created and
//     used within a single `execute` call on one thread — EXCEPT on
//     the `submit` path, where they move into the returned
//     `PjrtPending` and may cross to the thread that calls `wait`
//     (see that type's own Send audit below). No per-call object is
//     ever *shared* between two threads at once on either path.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

/// Device-resident constant inputs (w, e, parameter blocks) for every
/// bucket — the PJRT form of [`PreparedData`].
pub struct PjrtPrepared {
    /// Buffers in artifact input order minus `u`, one set per bucket.
    per_bucket: Vec<Vec<xla::PjRtBuffer>>,
    /// Source literals, kept alive for the async device copies.
    _literals: Vec<xla::Literal>,
}

// SAFETY: after `PjrtBackend::prepare` returns, every buffer's H2D copy
// has completed (it syncs before handing the value back) and the
// buffers and literals are only ever read — PJRT buffers are usable
// from any thread per the C API contract, and the wrapper-side
// obligation above covers the handle types. This makes per-SUT prepared
// constants shareable across the scheduler and its execute worker
// thread behind `Arc`.
unsafe impl Send for PjrtPrepared {}
unsafe impl Sync for PjrtPrepared {}

impl PreparedData for PjrtPrepared {
    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// One planned, dispatched, not-yet-synced bucket call of a submitted
/// execute: the output buffers plus everything that must stay alive
/// until the output sync (the CPU client's copy worker reads the
/// uploaded literal; the execution reads the input buffer).
struct PjrtChunkInFlight {
    bucket: usize,
    /// Real (unpadded) rows in this chunk.
    b: usize,
    /// `execute_b` output buffers, untouched until [`sync_chunk`].
    result: Vec<Vec<xla::PjRtBuffer>>,
    _u_lit: xla::Literal,
    _u_buf: xla::PjRtBuffer,
}

/// A submitted-but-unsynced PJRT execute ([`ExecBackend::submit`]):
/// every planned bucket chunk has been dispatched; [`PendingExecution::
/// wait`] performs the deferred output syncs in plan order.
pub struct PjrtPending {
    chunks: Vec<PjrtChunkInFlight>,
    calls: u64,
    rows_executed: u64,
    n_rows: usize,
}

// SAFETY: the handle moves (never shared — `wait` consumes it) from
// the submitting thread to the waiting thread. The PJRT C API allows
// buffers and their `ToLiteralSync` readback to be used from any
// thread; the wrapper-side handle audit is the same one documented on
// `PjrtBackend` above (uninhabited enums in the in-repo STUB — the
// claim is vacuously true there — and a raw-handle check required for
// any real binding). The `Literal` held for the async H2D copy is
// plain owned host memory. Re-audit alongside the impls above whenever
// the `xla` binding changes.
unsafe impl Send for PjrtPending {}

/// The deferred half of a chunk execute: sync the output tuple, demux
/// to per-row [`Perf`]s, and only then drop the chunk's input literal
/// and buffers (the sync guarantees the device is done reading them).
fn sync_chunk(chunk: PjrtChunkInFlight) -> Result<Vec<Perf>> {
    let tuple = chunk.result[0][0].to_literal_sync()?;
    let (thr_lit, lat_lit) = tuple.to_tuple2()?;
    let thr = thr_lit.to_vec::<f32>()?;
    let lat = lat_lit.to_vec::<f32>()?;
    if thr.len() != chunk.bucket || lat.len() != chunk.bucket {
        return Err(ActsError::Artifact(format!(
            "artifact returned {} outputs for bucket {}",
            thr.len(),
            chunk.bucket
        )));
    }
    Ok(thr[..chunk.b]
        .iter()
        .zip(&lat[..chunk.b])
        .map(|(&t, &l)| Perf { throughput: t as f64, latency: l as f64 })
        .collect())
}

impl PendingExecution for PjrtPending {
    fn wait(self: Box<Self>) -> Result<Execution> {
        let this = *self;
        let mut perfs = Vec::with_capacity(this.n_rows);
        for chunk in this.chunks {
            perfs.extend(sync_chunk(chunk)?);
        }
        Ok(Execution {
            perfs,
            execute_calls: this.calls,
            rows_executed: this.rows_executed,
        })
    }
}

impl PjrtBackend {
    /// Load and compile every bucket artifact from `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<PjrtBackend> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu()?;
        let mut execs = Vec::with_capacity(BUCKETS.len());
        for &bucket in BUCKETS.iter() {
            let path = dir.join(shapes::artifact_name(bucket));
            if !path.exists() {
                return Err(ActsError::Artifact(format!(
                    "{} missing — run `make artifacts` first",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| ActsError::Artifact("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            execs.push((bucket, exe));
        }
        Ok(PjrtBackend { client, execs, artifacts_dir: dir })
    }

    /// The artifacts directory this backend loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Dispatch one planned call without syncing its outputs:
    /// `configs.len() <= bucket` rows, padded up to `bucket` with
    /// copies of row 0 (cheap, valid data). The input upload is still
    /// awaited (the CPU client has no other safe completion signal for
    /// the H2D copy); only the *output* sync is deferred to
    /// [`sync_chunk`], which is what lets several submitted executes
    /// proceed on-device concurrently.
    fn submit_chunk(
        &self,
        prepared: &PjrtPrepared,
        configs: &[&[f32]],
        bucket: usize,
        device: &xla::PjRtDevice,
        scratch: &mut Vec<f32>,
    ) -> Result<PjrtChunkInFlight> {
        let b = configs.len();
        debug_assert!(b >= 1 && b <= bucket);
        let bucket_pos = BUCKETS.iter().position(|&k| k == bucket).expect("planned bucket");
        let exe = &self.execs[bucket_pos].1;
        let consts = &prepared.per_bucket[bucket_pos];

        // u: bucket rows in the reusable scratch buffer
        scratch.clear();
        scratch.reserve(bucket * D_PAD);
        for c in configs {
            scratch.extend_from_slice(c);
        }
        for _ in b..bucket {
            scratch.extend_from_slice(configs[0]);
        }
        // NB: go through a Literal (buffer_from_host_buffer may zero-copy
        // and alias the host memory) and keep `u_lit` alive until the
        // output sync — the CPU client's CopyFromLiteral reads it from a
        // worker thread. The Literal owns its copy, so `scratch` is free
        // for the plan's next call immediately.
        let u_lit = xla::Literal::vec1(&scratch[..]).reshape(&[bucket as i64, D_PAD as i64])?;
        let u_buf = self.client.buffer_from_host_literal(Some(device), &u_lit)?;
        // await the async H2D copy (readback sync; CopyRawToHost is not
        // implemented on this CPU client) so u_lit cannot be freed under
        // the copy thread on any early-return path
        let _ = u_buf.to_literal_sync()?;

        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(consts.len() + 1);
        inputs.push(&u_buf);
        inputs.extend(consts.iter());

        let result = exe.execute_b::<&xla::PjRtBuffer>(&inputs)?;
        // u_lit and u_buf ride along in the in-flight chunk: they may
        // not drop until the output sync proves the device is done
        Ok(PjrtChunkInFlight { bucket, b, result, _u_lit: u_lit, _u_buf: u_buf })
    }

    /// Execute one planned call synchronously: dispatch + output sync.
    fn execute_chunk(
        &self,
        prepared: &PjrtPrepared,
        configs: &[&[f32]],
        bucket: usize,
        device: &xla::PjRtDevice,
        scratch: &mut Vec<f32>,
    ) -> Result<Vec<Perf>> {
        sync_chunk(self.submit_chunk(prepared, configs, bucket, device, scratch)?)
    }

    /// Shared downcast for the execute/submit entry points.
    fn own_prepared<'p>(&self, prepared: &'p dyn PreparedData) -> Result<&'p PjrtPrepared> {
        prepared.as_any().downcast_ref::<PjrtPrepared>().ok_or_else(|| {
            ActsError::InvalidArg("prepared constants do not belong to the pjrt backend".into())
        })
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload the constant inputs (w, e, and every parameter block) to
    /// device-resident buffers, once per bucket.
    fn prepare(
        &self,
        params: &SurfaceParams,
        w: &[f32],
        e: &[f32],
    ) -> Result<Box<dyn PreparedData>> {
        let devices = self.client.devices();
        let device = &devices[0];
        let mut per_bucket = Vec::with_capacity(BUCKETS.len());
        // NB: the CPU client's CopyFromLiteral is ASYNC — a worker thread
        // reads from the Literal after buffer_from_host_literal returns,
        // so every uploaded literal is kept alive inside PjrtPrepared.
        let mut literals = Vec::new();
        for &bucket in BUCKETS.iter() {
            let mut upload = |idx: usize, data: &[f32]| -> Result<xla::PjRtBuffer> {
                let dims: Vec<i64> =
                    shapes::dims_for(idx, bucket).iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data).reshape(&dims)?;
                let buf = self.client.buffer_from_host_literal(Some(device), &lit)?;
                literals.push(lit);
                Ok(buf)
            };
            let mut bufs = Vec::with_capacity(shapes::INPUT_SPEC.len() - 1);
            bufs.push(upload(1, w)?);
            bufs.push(upload(2, e)?);
            for (idx, slice) in params.fields() {
                bufs.push(upload(idx, slice)?);
            }
            per_bucket.push(bufs);
        }
        // force every async H2D copy to complete before returning: a
        // prepared set dropped mid-transfer would free the source
        // literals under the copy thread (observed SIGSEGV otherwise)
        for bufs in &per_bucket {
            for buf in bufs {
                let _ = buf.to_literal_sync()?;
            }
        }
        Ok(Box::new(PjrtPrepared { per_bucket, _literals: literals }))
    }

    /// Execute a batch: the rows are split greedily across the compiled
    /// buckets ([`shapes::plan_buckets`]) — exact chunks of the largest
    /// fitting bucket, with at most one padded call for the remainder —
    /// so a B=40 request executes as 3×16 rows, not one 256-row call.
    /// The device handle is resolved once per batch and one upload
    /// scratch buffer is reused across the plan's calls.
    fn execute(&self, prepared: &dyn PreparedData, rows: &[&[f32]]) -> Result<Execution> {
        let prepared = self.own_prepared(prepared)?;
        // one devices() resolution (it allocates a Vec) per batch, not
        // per chunk
        let devices = self.client.devices();
        let device = &devices[0];
        let mut scratch: Vec<f32> = Vec::new();
        let mut perfs = Vec::with_capacity(rows.len());
        let mut offset = 0usize;
        let mut calls = 0u64;
        let mut rows_executed = 0u64;
        for bucket in shapes::plan_buckets(rows.len()) {
            let take = bucket.min(rows.len() - offset);
            let chunk = &rows[offset..offset + take];
            offset += take;
            perfs.extend(self.execute_chunk(prepared, chunk, bucket, device, &mut scratch)?);
            calls += 1;
            rows_executed += bucket as u64;
        }
        debug_assert_eq!(offset, rows.len(), "plan must consume every row");
        Ok(Execution { perfs, execute_calls: calls, rows_executed })
    }

    /// The async submission path: dispatch every planned bucket chunk
    /// (input uploads awaited, outputs left on-device) and defer all
    /// output syncs to the returned handle's `wait`. Between `submit`
    /// and `wait`, this call's executes overlap with anything else the
    /// caller submits — the whole point of the streaming scheduler's
    /// continuously-draining queue.
    fn submit<'a>(
        &'a self,
        prepared: &'a dyn PreparedData,
        rows: &[&[f32]],
    ) -> Result<Box<dyn PendingExecution + 'a>> {
        let prepared = self.own_prepared(prepared)?;
        let devices = self.client.devices();
        let device = &devices[0];
        let mut scratch: Vec<f32> = Vec::new();
        let mut chunks = Vec::new();
        let mut offset = 0usize;
        let mut calls = 0u64;
        let mut rows_executed = 0u64;
        for bucket in shapes::plan_buckets(rows.len()) {
            let take = bucket.min(rows.len() - offset);
            let chunk = &rows[offset..offset + take];
            offset += take;
            chunks.push(self.submit_chunk(prepared, chunk, bucket, device, &mut scratch)?);
            calls += 1;
            rows_executed += bucket as u64;
        }
        debug_assert_eq!(offset, rows.len(), "plan must consume every row");
        Ok(Box::new(PjrtPending { chunks, calls, rows_executed, n_rows: rows.len() }))
    }
}
