//! The backend conformance suite: the reusable checklist any
//! [`ExecBackend`] must pass — the paved road for future GPU / real-
//! PJRT backends, promoted out of the scattered per-backend golden
//! tests.
//!
//! Each check is a standalone function taking a `label` (so a failed
//! assertion names the backend under test) and the backend; `run_suite`
//! strings the standard checklist together. The integration harness in
//! `rust/tests/conformance.rs` instantiates the suite for
//! native-scalar, native-simd, chaos-wrapping-native (a zero-fault
//! plan must be transparent) and the PJRT stub (skip-loudly).
//!
//! The contracts, in suite order:
//!
//! 1. **Golden-oracle parity** — outputs match the committed numpy
//!    reference within the repo-wide tolerances (`1e-3 * (1 + |want|)`
//!    by default, the same scheme as `rust/tests/runtime_golden.rs`).
//! 2. **Bitwise batch-size invariance** — a row's result is identical
//!    whether evaluated alone, in a prefix, or in a full batch. This is
//!    what lets the scheduler coalesce, pipeline and stream without
//!    changing results.
//! 3. **Bitwise run-to-run determinism** — repeated prepare/execute
//!    over identical inputs reproduce every bit (checkpoint/resume
//!    identity depends on it).
//! 4. **Cost accounting** — `execute_calls` / `rows_executed` are
//!    populated sanely; backends that promise one-call-no-padding
//!    batches (native) are held to it exactly.
//! 5. **Foreign-`PreparedData` rejection** — constants prepared by a
//!    different backend are an error, never misinterpreted memory.
//!
//! Pairwise identity between two *instances* of the same path (solo vs
//! threaded, bare vs chaos-wrapped) is [`check_pairwise_identity`],
//! invoked by the harness where the pairing makes sense.

use super::backend::{ExecBackend, PreparedData};
use super::engine::Perf;
use super::golden;
use std::any::Any;
use std::path::{Path, PathBuf};

/// Knobs for [`run_suite`].
pub struct SuiteOptions {
    /// Golden oracle file to check parity against (`None` skips the
    /// parity check — the other contracts are still enforced).
    pub golden: Option<PathBuf>,
    /// Relative tolerance for golden parity, applied as
    /// `|got - want| < tol * (1 + |want|)`.
    pub golden_rel_tol: f64,
    /// Hold the backend to exactly one physical call and zero padding
    /// per batch (true for native; PJRT's bucket planner may split and
    /// pad).
    pub exact_cost: bool,
}

impl Default for SuiteOptions {
    fn default() -> Self {
        SuiteOptions { golden: None, golden_rel_tol: 1e-3, exact_cost: false }
    }
}

/// Prepare the patterned binding and execute its `b` rows.
fn eval_pattern(backend: &dyn ExecBackend, b: usize) -> Vec<Perf> {
    let (configs, w, e, params) = golden::pattern_call(b);
    let prepared = backend.prepare(&params, &w, &e).expect("prepare");
    let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
    backend.execute(prepared.as_ref(), &rows).expect("execute").perfs
}

/// Contract 1: outputs match the committed golden oracle within
/// `rel_tol * (1 + |want|)` for every batch size the oracle records.
pub fn check_golden_parity(label: &str, backend: &dyn ExecBackend, path: &Path, rel_tol: f64) {
    let cases = golden::parse_golden(path).expect("golden oracle parses");
    assert!(!cases.is_empty(), "{label}: golden oracle {} is empty", path.display());
    for case in &cases {
        let perfs = eval_pattern(backend, case.b);
        assert_eq!(perfs.len(), case.b, "{label}: row count for b={}", case.b);
        for (i, p) in perfs.iter().enumerate() {
            let (wt, wl) = (case.thr[i], case.lat[i]);
            assert!(
                (p.throughput - wt).abs() < rel_tol * (1.0 + wt.abs()),
                "{label}: thr[{i}] at b={}: {} vs oracle {wt}",
                case.b,
                p.throughput
            );
            assert!(
                (p.latency - wl).abs() < rel_tol * (1.0 + wl.abs()),
                "{label}: lat[{i}] at b={}: {} vs oracle {wl}",
                case.b,
                p.latency
            );
        }
    }
}

/// Contract 2: a row's result is bitwise identical alone, in a prefix,
/// and in a full batch.
pub fn check_batch_invariance(label: &str, backend: &dyn ExecBackend) {
    let (configs, w, e, params) = golden::pattern_call(16);
    let prepared = backend.prepare(&params, &w, &e).expect("prepare");
    let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
    let all = backend.execute(prepared.as_ref(), &rows).expect("execute").perfs;
    for (i, row) in rows.iter().enumerate() {
        let one = backend.execute(prepared.as_ref(), &[row]).expect("execute").perfs;
        assert_eq!(one[0], all[i], "{label}: row {i} must be batch-size invariant bitwise");
    }
    let prefix = backend.execute(prepared.as_ref(), &rows[..7]).expect("execute").perfs;
    assert_eq!(&prefix[..], &all[..7], "{label}: a prefix batch must match bitwise");
}

/// Contract 3: independent prepare/execute rounds over identical
/// inputs reproduce every bit — both the premix and the row loop.
pub fn check_determinism(label: &str, backend: &dyn ExecBackend) {
    let (configs, w, e, params) = golden::pattern_call(16);
    let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
    let p1 = backend.prepare(&params, &w, &e).expect("prepare");
    let p2 = backend.prepare(&params, &w, &e).expect("prepare");
    let a = backend.execute(p1.as_ref(), &rows).expect("execute").perfs;
    let b = backend.execute(p1.as_ref(), &rows).expect("execute").perfs;
    let c = backend.execute(p2.as_ref(), &rows).expect("execute").perfs;
    assert_eq!(a, b, "{label}: repeated execute must be bitwise deterministic");
    assert_eq!(a, c, "{label}: repeated prepare must be bitwise deterministic");
}

/// Two instances that claim the same evaluation path (solo vs
/// threaded, bare vs zero-fault chaos wrapper) must agree bitwise,
/// below and above any internal parallelism threshold.
pub fn check_pairwise_identity(label: &str, a: &dyn ExecBackend, b: &dyn ExecBackend) {
    let (configs, w, e, params) = golden::pattern_call(16);
    let mut big: Vec<Vec<f32>> = Vec::new();
    while big.len() < 300 {
        big.extend(configs.iter().cloned());
    }
    big.truncate(300);
    for take in [1usize, 16, 300] {
        let rows: Vec<&[f32]> = big.iter().take(take).map(|c| c.as_slice()).collect();
        let pa = a.prepare(&params, &w, &e).expect("prepare");
        let pb = b.prepare(&params, &w, &e).expect("prepare");
        let ra = a.execute(pa.as_ref(), &rows).expect("execute").perfs;
        let rb = b.execute(pb.as_ref(), &rows).expect("execute").perfs;
        assert_eq!(ra, rb, "{label}: instances diverged at batch size {take}");
    }
}

/// Contract 4: the physical-cost report is sane; `exact` additionally
/// holds the backend to one call and zero padding per batch.
pub fn check_cost_accounting(label: &str, backend: &dyn ExecBackend, exact: bool) {
    let (configs, w, e, params) = golden::pattern_call(10);
    let prepared = backend.prepare(&params, &w, &e).expect("prepare");
    let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
    let out = backend.execute(prepared.as_ref(), &rows).expect("execute");
    assert_eq!(out.perfs.len(), 10, "{label}: one Perf per requested row");
    assert!(out.execute_calls >= 1, "{label}: at least one physical call");
    assert!(out.rows_executed >= 10, "{label}: padding can only add rows");
    if exact {
        assert_eq!(out.execute_calls, 1, "{label}: one batch must be one physical call");
        assert_eq!(out.rows_executed, 10, "{label}: this backend must never pad");
    }
}

/// Contract 5: constants prepared by a different backend are an error.
pub fn check_foreign_prepared_rejection(label: &str, backend: &dyn ExecBackend) {
    struct ForeignPrepared;
    impl PreparedData for ForeignPrepared {
        fn as_any(&self) -> &dyn Any {
            self
        }
    }
    let (configs, ..) = golden::pattern_call(1);
    let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
    assert!(
        backend.execute(&ForeignPrepared, &rows).is_err(),
        "{label}: foreign PreparedData must be rejected, never misinterpreted"
    );
}

/// The standard checklist (contracts 1–5 above, golden parity only
/// when [`SuiteOptions::golden`] is set).
pub fn run_suite(label: &str, backend: &dyn ExecBackend, opts: &SuiteOptions) {
    if let Some(path) = &opts.golden {
        check_golden_parity(label, backend, path, opts.golden_rel_tol);
    }
    check_batch_invariance(label, backend);
    check_determinism(label, backend);
    check_cost_accounting(label, backend, opts.exact_cost);
    check_foreign_prepared_rejection(label, backend);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeBackend;
    use crate::runtime::simd::SimdMode;

    /// The suite's own plumbing, exercised on the always-available
    /// scalar backend (the full per-backend instantiations live in the
    /// conformance integration test).
    #[test]
    fn suite_passes_on_native_scalar() {
        let backend = NativeBackend::with_options(1, SimdMode::Scalar).unwrap();
        let opts = SuiteOptions { exact_cost: true, ..SuiteOptions::default() };
        run_suite("native-scalar (unit)", &backend, &opts);
    }

    #[test]
    fn pairwise_identity_covers_thread_counts() {
        let solo = NativeBackend::with_options(1, SimdMode::Scalar).unwrap();
        let multi = NativeBackend::with_options(4, SimdMode::Scalar).unwrap();
        check_pairwise_identity("native-scalar solo-vs-threaded (unit)", &solo, &multi);
    }
}
