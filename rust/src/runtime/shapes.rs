//! Artifact dimension table — the rust mirror of
//! `python/compile/model.py::INPUT_SPEC` and `kernels/__init__.py` dims.
//! The `runtime_golden` integration test cross-checks this table against
//! `artifacts/shapes.txt` emitted by the AOT step, so drift fails CI.

/// Padded knob dimension.
pub const D_PAD: usize = 64;
/// RBF bump count.
pub const J: usize = 32;
/// Cliff terms.
pub const R: usize = 8;
/// Dominance gates.
pub const G: usize = 4;
/// Stacked direction rows (cliffs + gates).
pub const RG: usize = R + G;
/// Workload feature dimension.
pub const W_DIM: usize = 8;
/// Deployment feature dimension.
pub const E_DIM: usize = 4;
/// Head constants: [t_scale, lat0, lat1, t_sat].
pub const N_CONSTS: usize = 4;

/// Static batch buckets with a compiled executable each.
pub const BUCKETS: [usize; 4] = [1, 16, 256, 2048];

/// Artifact input table: (name, dims) with 0 standing for the batch dim.
pub const INPUT_SPEC: &[(&str, &[usize])] = &[
    ("u", &[0, D_PAD]),
    ("w", &[W_DIM]),
    ("e", &[E_DIM]),
    ("m", &[4, D_PAD, W_DIM]),
    ("step_s", &[D_PAD]),
    ("step_t", &[D_PAD]),
    ("qs", &[W_DIM, D_PAD, D_PAD]),
    ("centers", &[J, D_PAD]),
    ("inv_rho2", &[J]),
    ("amps_w", &[J, W_DIM]),
    ("dirs", &[RG, D_PAD]),
    ("cliff_tau", &[R]),
    ("cliff_kappa", &[R]),
    ("cliff_gain_w", &[R, W_DIM]),
    ("cliff_gain_e", &[R, E_DIM]),
    ("gate_tau", &[G]),
    ("gate_kappa", &[G]),
    ("gate_floor_w", &[G, W_DIM]),
    ("dep_w", &[E_DIM]),
    ("consts", &[N_CONSTS]),
];

/// Concrete dims of input `idx` for batch size `b`.
pub fn dims_for(idx: usize, b: usize) -> Vec<usize> {
    INPUT_SPEC[idx].1.iter().map(|&d| if d == 0 { b } else { d }).collect()
}

/// Element count of input `idx` for batch size `b`.
pub fn len_for(idx: usize, b: usize) -> usize {
    dims_for(idx, b).iter().product()
}

/// Smallest bucket that fits `b` requested rows, if any.
pub fn bucket_for(b: usize) -> Option<usize> {
    BUCKETS.iter().copied().find(|&cap| cap >= b)
}

/// Artifact file name for a bucket.
pub fn artifact_name(bucket: usize) -> String {
    format!("surface_b{bucket}.hlo.txt")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_count_matches_python() {
        assert_eq!(INPUT_SPEC.len(), 20);
    }

    #[test]
    fn dims_substitute_batch() {
        assert_eq!(dims_for(0, 256), vec![256, 64]);
        assert_eq!(dims_for(6, 256), vec![8, 64, 64]); // qs has no batch dim
        assert_eq!(len_for(0, 16), 16 * 64);
        assert_eq!(len_for(19, 1), 4);
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_for(1), Some(1));
        assert_eq!(bucket_for(2), Some(16));
        assert_eq!(bucket_for(16), Some(16));
        assert_eq!(bucket_for(17), Some(256));
        assert_eq!(bucket_for(2048), Some(2048));
        assert_eq!(bucket_for(2049), None);
    }

    #[test]
    fn buckets_are_sorted_ascending() {
        let mut s = BUCKETS.to_vec();
        s.sort_unstable();
        assert_eq!(s, BUCKETS.to_vec());
    }
}
