//! Artifact dimension table — the rust mirror of
//! `python/compile/model.py::INPUT_SPEC` and `kernels/__init__.py` dims.
//! The `runtime_golden` integration test cross-checks this table against
//! `artifacts/shapes.txt` emitted by the AOT step, so drift fails CI.

/// Padded knob dimension.
pub const D_PAD: usize = 64;
/// RBF bump count.
pub const J: usize = 32;
/// Cliff terms.
pub const R: usize = 8;
/// Dominance gates.
pub const G: usize = 4;
/// Stacked direction rows (cliffs + gates).
pub const RG: usize = R + G;
/// Workload feature dimension.
pub const W_DIM: usize = 8;
/// Deployment feature dimension.
pub const E_DIM: usize = 4;
/// Head constants: [t_scale, lat0, lat1, t_sat].
pub const N_CONSTS: usize = 4;

/// Static batch buckets with a compiled executable each.
pub const BUCKETS: [usize; 4] = [1, 16, 256, 2048];

/// Artifact input table: (name, dims) with 0 standing for the batch dim.
pub const INPUT_SPEC: &[(&str, &[usize])] = &[
    ("u", &[0, D_PAD]),
    ("w", &[W_DIM]),
    ("e", &[E_DIM]),
    ("m", &[4, D_PAD, W_DIM]),
    ("step_s", &[D_PAD]),
    ("step_t", &[D_PAD]),
    ("qs", &[W_DIM, D_PAD, D_PAD]),
    ("centers", &[J, D_PAD]),
    ("inv_rho2", &[J]),
    ("amps_w", &[J, W_DIM]),
    ("dirs", &[RG, D_PAD]),
    ("cliff_tau", &[R]),
    ("cliff_kappa", &[R]),
    ("cliff_gain_w", &[R, W_DIM]),
    ("cliff_gain_e", &[R, E_DIM]),
    ("gate_tau", &[G]),
    ("gate_kappa", &[G]),
    ("gate_floor_w", &[G, W_DIM]),
    ("dep_w", &[E_DIM]),
    ("consts", &[N_CONSTS]),
];

/// Concrete dims of input `idx` for batch size `b`.
pub fn dims_for(idx: usize, b: usize) -> Vec<usize> {
    INPUT_SPEC[idx].1.iter().map(|&d| if d == 0 { b } else { d }).collect()
}

/// Element count of input `idx` for batch size `b`.
pub fn len_for(idx: usize, b: usize) -> usize {
    dims_for(idx, b).iter().product()
}

/// Smallest bucket that fits `b` requested rows, if any.
pub fn bucket_for(b: usize) -> Option<usize> {
    BUCKETS.iter().copied().find(|&cap| cap >= b)
}

/// Per-call dispatch overhead expressed in row-equivalents: padding up
/// to this many extra rows into one larger-bucket call is cheaper than
/// splitting the remainder into more calls (the hot-path bench puts a
/// B=1 dispatch at roughly the cost of a handful of B=16 rows).
pub const PAD_SLACK_ROWS: usize = 16;

/// Greedy multi-bucket execution plan for a batch of `b` rows: the
/// bucket sizes of the calls that cover the batch, in issue order. Each
/// call consumes `min(bucket, rows left)` source rows; only the final
/// call may pad.
///
/// Strategy per remainder: an exact bucket match ends the plan; else,
/// if padding up to the smallest covering bucket wastes no more than
/// `max(remainder, PAD_SLACK_ROWS)` rows, one padded call ends the plan
/// (40 rows must *not* execute as 256 — but 2047 rows *should* execute
/// as one 2048 call); otherwise split off an exact chunk of the largest
/// fitting bucket and recurse. The seed behaviour (round every request
/// up to one covering bucket) executed up to 6.4x the requested rows.
pub fn plan_buckets(b: usize) -> Vec<usize> {
    assert!(b > 0, "plan_buckets needs at least one row");
    let mut plan = Vec::new();
    let mut rem = b;
    while rem > 0 {
        if BUCKETS.contains(&rem) {
            plan.push(rem);
            break;
        }
        if let Some(cover) = bucket_for(rem) {
            if cover - rem <= PAD_SLACK_ROWS.max(rem) {
                plan.push(cover);
                break;
            }
        }
        let exact = BUCKETS
            .iter()
            .rev()
            .find(|&&k| k < rem)
            .copied()
            .expect("BUCKETS start at 1, so any rem > 1 has an exact chunk");
        plan.push(exact);
        rem -= exact;
    }
    plan
}

/// Artifact file name for a bucket.
pub fn artifact_name(bucket: usize) -> String {
    format!("surface_b{bucket}.hlo.txt")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_count_matches_python() {
        assert_eq!(INPUT_SPEC.len(), 20);
    }

    #[test]
    fn dims_substitute_batch() {
        assert_eq!(dims_for(0, 256), vec![256, 64]);
        assert_eq!(dims_for(6, 256), vec![8, 64, 64]); // qs has no batch dim
        assert_eq!(len_for(0, 16), 16 * 64);
        assert_eq!(len_for(19, 1), 4);
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_for(1), Some(1));
        assert_eq!(bucket_for(2), Some(16));
        assert_eq!(bucket_for(16), Some(16));
        assert_eq!(bucket_for(17), Some(256));
        assert_eq!(bucket_for(2048), Some(2048));
        assert_eq!(bucket_for(2049), None);
    }

    #[test]
    fn buckets_are_sorted_ascending() {
        let mut s = BUCKETS.to_vec();
        s.sort_unstable();
        assert_eq!(s, BUCKETS.to_vec());
    }

    #[test]
    fn plan_exact_bucket_sizes_are_single_calls() {
        for &b in BUCKETS.iter() {
            assert_eq!(plan_buckets(b), vec![b]);
        }
    }

    #[test]
    fn plan_splits_odd_batches_instead_of_padding_wide() {
        // the ISSUE case: 40 rows must not execute 256 padded rows
        assert_eq!(plan_buckets(40), vec![16, 16, 16]); // 48 rows, 3 calls
        assert_eq!(plan_buckets(17), vec![16, 1]); // 17 rows, 2 calls
        assert_eq!(plan_buckets(30), vec![16, 16]); // 32 rows
        assert_eq!(plan_buckets(272), vec![256, 16]); // exact split
    }

    #[test]
    fn plan_pads_when_waste_is_small() {
        assert_eq!(plan_buckets(2), vec![16]); // 2 single-row calls lose
        assert_eq!(plan_buckets(8), vec![16]);
        assert_eq!(plan_buckets(255), vec![256]);
        assert_eq!(plan_buckets(2047), vec![2048]); // not 23 small calls
    }

    #[test]
    fn plan_chunks_above_the_largest_bucket() {
        assert_eq!(plan_buckets(4096), vec![2048, 2048]);
        assert_eq!(plan_buckets(2049), vec![2048, 1]);
        assert_eq!(plan_buckets(2050), vec![2048, 16]);
    }

    #[test]
    fn plan_always_covers_the_batch_and_every_call_is_a_bucket() {
        for b in 1..600 {
            let plan = plan_buckets(b);
            assert!(plan.iter().all(|k| BUCKETS.contains(k)), "b={b}: {plan:?}");
            // walking the plan consumes exactly b source rows
            let mut rem = b;
            for (i, &k) in plan.iter().enumerate() {
                let take = k.min(rem);
                assert!(take > 0, "b={b}: empty call {i} in {plan:?}");
                // only the final call may pad
                if take < k {
                    assert_eq!(i, plan.len() - 1, "b={b}: padding mid-plan {plan:?}");
                }
                rem -= take;
            }
            assert_eq!(rem, 0, "b={b}: plan {plan:?} does not cover");
            // executed rows stay within one PAD_SLACK_ROWS of the request
            // unless the request itself was tiny
            let rows: usize = plan.iter().sum();
            assert!(
                rows <= b + PAD_SLACK_ROWS.max(b),
                "b={b}: plan {plan:?} executes {rows} rows"
            );
        }
    }
}
