//! Deterministic fault injection: a [`ChaosBackend`] wrapper that
//! perturbs any inner [`ExecBackend`] according to a seeded
//! [`FaultPlan`].
//!
//! This is the test harness for the whole fault-tolerance stack: the
//! engine's retry/deadline policy, the scheduler's poisoned-round
//! degradation and session quarantine, and the chaos CI smoke all drive
//! their failures through here. Fault decisions are **deterministic per
//! execute index**: the wrapper numbers every `execute` call with a
//! monotone counter and derives an independent [`Rng64`] stream from
//! `plan.seed ^ index`, so two runs with the same plan, the same seed
//! and the same call sequence inject byte-identical faults — which is
//! what makes retry-counter assertions and chaos e2e tests repeatable.
//!
//! Because each *retry* issues a fresh `execute` (a new index), a
//! transient fault at index `i` does not condemn the retried call at
//! index `i+1`: with `transient_p = 0.1` and 4 attempts the chance a
//! row round is lost is `1e-4`, the behaviour real flaky substrates
//! show and the one the engine's [`crate::runtime::engine::RetryPolicy`]
//! is built to absorb.
//!
//! Fault classes, in priority order when several fire on one index:
//!
//! 1. **panic** — the execute unwinds, modelling a crashing worker; the
//!    scheduler's `catch_unwind` degradation path owns this.
//! 2. **hang** — the execute sleeps far past any sane deadline; the
//!    engine's per-execute deadline kills the call instead of wedging
//!    the lane.
//! 3. **persistent** — every execute from `persistent_after` onward
//!    fails, modelling a dead device that no retry cures.
//! 4. **transient** — this execute fails, the next may succeed; the
//!    retry policy's bread and butter.
//! 5. **latency** — the execute succeeds after an injected stall,
//!    exercising backoff/deadline interplay without failing anything.

use super::backend::{ExecBackend, Execution, PendingExecution, PreparedData};
use super::engine::SurfaceParams;
use crate::error::{ActsError, Result};
use crate::util::rng::Rng64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Seeded description of which faults a [`ChaosBackend`] injects and
/// how often. Probabilities are per execute call, drawn independently
/// per fault class from the call's own derived rng stream.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// Root seed; same seed + same call sequence = same faults.
    pub seed: u64,
    /// Probability an execute fails transiently (retryable).
    pub transient_p: f64,
    /// If set, every execute with index >= this fails persistently.
    pub persistent_after: Option<u64>,
    /// Probability an execute is delayed by [`FaultPlan::latency`].
    pub latency_p: f64,
    /// Injected stall for latency faults.
    pub latency: Duration,
    /// Probability an execute hangs for [`FaultPlan::hang`].
    pub hang_p: f64,
    /// Injected stall for hang faults — pick this far above the
    /// engine's deadline so the deadline, not the sleep, ends the call.
    pub hang: Duration,
    /// Probability an execute panics (models a crashing worker).
    pub panic_p: f64,
    /// If set, every execute with index >= this panics — the
    /// crash-looping device the scheduler's quarantine exists for.
    /// Point it past a session's baseline executes so the crash loop
    /// starts once tuning rounds are under way.
    pub panic_after: Option<u64>,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 0,
            transient_p: 0.0,
            persistent_after: None,
            latency_p: 0.0,
            latency: Duration::from_millis(1),
            hang_p: 0.0,
            hang: Duration::from_secs(3600),
            panic_p: 0.0,
            panic_after: None,
        }
    }
}

/// What a [`FaultPlan`] decided for one execute index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Pass through untouched.
    None,
    /// Unwind the execute.
    Panic,
    /// Sleep for the plan's hang duration, then fail.
    Hang,
    /// Fail: the device is gone, retries cannot cure it.
    Persistent,
    /// Fail this call only.
    Transient,
    /// Sleep for the plan's latency, then pass through.
    Latency,
}

impl FaultPlan {
    /// A quiet plan with only a seed set — builder-style starting point.
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Transient-only plan: the CI chaos smoke's shape.
    pub fn transient(seed: u64, p: f64) -> FaultPlan {
        FaultPlan { seed, transient_p: p, ..FaultPlan::default() }
    }

    /// The (deterministic) fault decision for execute number `index`.
    ///
    /// Each index gets an independent rng stream derived from the plan
    /// seed, so decisions do not depend on thread interleaving — only
    /// on how many executes preceded this one. Draws happen in a fixed
    /// class order (panic, hang, persistent, transient, latency) and
    /// the highest-priority hit wins.
    pub fn fault_for(&self, index: u64) -> Fault {
        let mut rng = Rng64::new(self.seed ^ index.wrapping_mul(0x9E3779B97F4A7C15));
        let panic = (self.panic_p > 0.0 && rng.bool(self.panic_p))
            || self.panic_after.is_some_and(|after| index >= after);
        let hang = self.hang_p > 0.0 && rng.bool(self.hang_p);
        let persistent = self.persistent_after.is_some_and(|after| index >= after);
        let transient = self.transient_p > 0.0 && rng.bool(self.transient_p);
        let latency = self.latency_p > 0.0 && rng.bool(self.latency_p);
        if panic {
            Fault::Panic
        } else if hang {
            Fault::Hang
        } else if persistent {
            Fault::Persistent
        } else if transient {
            Fault::Transient
        } else if latency {
            Fault::Latency
        } else {
            Fault::None
        }
    }
}

/// Counts of faults a [`ChaosBackend`] actually injected.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosStats {
    /// Execute calls that reached the wrapper.
    pub executes: u64,
    /// Transient failures injected.
    pub transient: u64,
    /// Persistent failures injected.
    pub persistent: u64,
    /// Latency stalls injected.
    pub latency: u64,
    /// Hangs injected.
    pub hangs: u64,
    /// Panics injected.
    pub panics: u64,
}

/// An [`ExecBackend`] wrapper that injects the faults a [`FaultPlan`]
/// prescribes into an inner backend. `prepare` passes straight through
/// (constant upload is not the failure surface under test); `execute`
/// numbers the call, consults the plan, and either injects or
/// delegates.
pub struct ChaosBackend {
    inner: Box<dyn ExecBackend>,
    plan: FaultPlan,
    executes: AtomicU64,
    transient: AtomicU64,
    persistent: AtomicU64,
    latency: AtomicU64,
    hangs: AtomicU64,
    panics: AtomicU64,
}

impl ChaosBackend {
    /// Wrap `inner` under `plan`.
    pub fn new(inner: Box<dyn ExecBackend>, plan: FaultPlan) -> ChaosBackend {
        ChaosBackend {
            inner,
            plan,
            executes: AtomicU64::new(0),
            transient: AtomicU64::new(0),
            persistent: AtomicU64::new(0),
            latency: AtomicU64::new(0),
            hangs: AtomicU64::new(0),
            panics: AtomicU64::new(0),
        }
    }

    /// The active plan.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Injection counters so far.
    pub fn stats(&self) -> ChaosStats {
        ChaosStats {
            executes: self.executes.load(Ordering::Relaxed),
            transient: self.transient.load(Ordering::Relaxed),
            persistent: self.persistent.load(Ordering::Relaxed),
            latency: self.latency.load(Ordering::Relaxed),
            hangs: self.hangs.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
        }
    }
}

impl ExecBackend for ChaosBackend {
    fn name(&self) -> &'static str {
        // keep the inner backend's registry identity: the wrapper is a
        // test harness, not a distinct execution substrate
        self.inner.name()
    }

    fn platform(&self) -> String {
        format!("chaos(seed={}) over {}", self.plan.seed, self.inner.platform())
    }

    fn simd_width(&self) -> u64 {
        // faults don't change the evaluator: report the wrapped path
        self.inner.simd_width()
    }

    fn prepare(
        &self,
        params: &SurfaceParams,
        w: &[f32],
        e: &[f32],
    ) -> Result<Box<dyn PreparedData>> {
        self.inner.prepare(params, w, e)
    }

    fn execute(&self, prepared: &dyn PreparedData, rows: &[&[f32]]) -> Result<Execution> {
        let index = self.executes.fetch_add(1, Ordering::Relaxed);
        match self.plan.fault_for(index) {
            Fault::None => self.inner.execute(prepared, rows),
            Fault::Panic => {
                self.panics.fetch_add(1, Ordering::Relaxed);
                panic!("chaos: injected panic at execute {index}");
            }
            Fault::Hang => {
                self.hangs.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(self.plan.hang);
                Err(ActsError::Xla(format!("chaos: injected hang at execute {index}")))
            }
            Fault::Persistent => {
                self.persistent.fetch_add(1, Ordering::Relaxed);
                Err(ActsError::Xla(format!(
                    "chaos: injected persistent fault at execute {index}"
                )))
            }
            Fault::Transient => {
                self.transient.fetch_add(1, Ordering::Relaxed);
                Err(ActsError::Xla(format!(
                    "chaos: injected transient fault at execute {index}"
                )))
            }
            Fault::Latency => {
                self.latency.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(self.plan.latency);
                self.inner.execute(prepared, rows)
            }
        }
    }

    /// Async submission keeps the same fault semantics as `execute`:
    /// the call is numbered and the fault injected **at submit time**
    /// (indices stay a pure function of submission order, so chaos
    /// drills are as repeatable under streaming as under the barriered
    /// modes); only a clean or latency-stalled call reaches the inner
    /// backend's own `submit`, preserving its overlap.
    fn submit<'a>(
        &'a self,
        prepared: &'a dyn PreparedData,
        rows: &[&[f32]],
    ) -> Result<Box<dyn PendingExecution + 'a>> {
        let index = self.executes.fetch_add(1, Ordering::Relaxed);
        match self.plan.fault_for(index) {
            Fault::None => self.inner.submit(prepared, rows),
            Fault::Panic => {
                self.panics.fetch_add(1, Ordering::Relaxed);
                panic!("chaos: injected panic at execute {index}");
            }
            Fault::Hang => {
                self.hangs.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(self.plan.hang);
                Err(ActsError::Xla(format!("chaos: injected hang at execute {index}")))
            }
            Fault::Persistent => {
                self.persistent.fetch_add(1, Ordering::Relaxed);
                Err(ActsError::Xla(format!(
                    "chaos: injected persistent fault at execute {index}"
                )))
            }
            Fault::Transient => {
                self.transient.fetch_add(1, Ordering::Relaxed);
                Err(ActsError::Xla(format!(
                    "chaos: injected transient fault at execute {index}"
                )))
            }
            Fault::Latency => {
                self.latency.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(self.plan.latency);
                self.inner.submit(prepared, rows)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::Engine;
    use crate::runtime::native::NativeBackend;

    #[test]
    fn fault_decisions_are_deterministic_per_index() {
        let plan = FaultPlan {
            transient_p: 0.3,
            latency_p: 0.2,
            hang_p: 0.05,
            panic_p: 0.05,
            ..FaultPlan::seeded(42)
        };
        let a: Vec<Fault> = (0..256).map(|i| plan.fault_for(i)).collect();
        let b: Vec<Fault> = (0..256).map(|i| plan.fault_for(i)).collect();
        assert_eq!(a, b);
        // decisions are a pure function of index, not of call order
        assert_eq!(plan.fault_for(17), a[17]);
    }

    #[test]
    fn quiet_plan_injects_nothing() {
        let plan = FaultPlan::seeded(7);
        assert!((0..1000).all(|i| plan.fault_for(i) == Fault::None));
    }

    #[test]
    fn transient_rate_tracks_probability() {
        let plan = FaultPlan::transient(9, 0.1);
        let hits = (0..10_000).filter(|&i| plan.fault_for(i) == Fault::Transient).count();
        assert!((800..1200).contains(&hits), "hits {hits}");
    }

    #[test]
    fn persistent_after_condemns_every_later_execute() {
        let plan = FaultPlan { persistent_after: Some(5), ..FaultPlan::seeded(3) };
        assert!((0..5).all(|i| plan.fault_for(i) == Fault::None));
        assert!((5..50).all(|i| plan.fault_for(i) == Fault::Persistent));
    }

    #[test]
    fn panic_after_condemns_every_later_execute() {
        let plan = FaultPlan { panic_after: Some(3), ..FaultPlan::seeded(2) };
        assert!((0..3).all(|i| plan.fault_for(i) == Fault::None));
        assert!((3..20).all(|i| plan.fault_for(i) == Fault::Panic));
    }

    #[test]
    fn chaos_backend_passes_clean_executes_through_bitwise() {
        let clean = Engine::native().unwrap();
        let chaotic = Engine::from_backend(Box::new(ChaosBackend::new(
            Box::new(NativeBackend::new().unwrap()),
            FaultPlan::seeded(1),
        )));
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(8);
        let want = clean.evaluate(&params, &w, &e, &configs).unwrap();
        let got = chaotic.evaluate(&params, &w, &e, &configs).unwrap();
        assert_eq!(want, got, "a quiet chaos wrapper must be invisible");
    }

    #[test]
    fn chaos_backend_injects_and_counts_transients() {
        let plan = FaultPlan::transient(11, 1.0); // every execute fails
        let backend = ChaosBackend::new(Box::new(NativeBackend::new().unwrap()), plan);
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(2);
        let prepared = backend.prepare(&params, &w, &e).unwrap();
        let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
        let err = backend.execute(prepared.as_ref(), &rows).unwrap_err();
        assert!(err.to_string().contains("transient"), "{err}");
        assert_eq!(backend.stats().executes, 1);
        assert_eq!(backend.stats().transient, 1);
    }

    #[test]
    fn chaos_submit_numbers_and_injects_exactly_like_execute() {
        let plan = FaultPlan::transient(11, 1.0); // every call fails
        let backend = ChaosBackend::new(Box::new(NativeBackend::new().unwrap()), plan);
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(2);
        let prepared = backend.prepare(&params, &w, &e).unwrap();
        let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
        // submit injects at submit time (before any wait) and advances
        // the same execute counter the sync path uses
        let err = backend.submit(prepared.as_ref(), &rows).map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("transient fault at execute 0"), "{err}");
        assert_eq!(backend.stats().executes, 1);
        assert_eq!(backend.stats().transient, 1);
    }

    #[test]
    fn chaos_submit_passes_clean_calls_through_bitwise() {
        let backend =
            ChaosBackend::new(Box::new(NativeBackend::new().unwrap()), FaultPlan::seeded(1));
        let clean = NativeBackend::new().unwrap();
        let (configs, w, e, params) = crate::runtime::golden::pattern_call(4);
        let rows: Vec<&[f32]> = configs.iter().map(|c| c.as_slice()).collect();
        let chaos_prep = backend.prepare(&params, &w, &e).unwrap();
        let clean_prep = clean.prepare(&params, &w, &e).unwrap();
        let want = clean.execute(clean_prep.as_ref(), &rows).unwrap();
        let got = backend.submit(chaos_prep.as_ref(), &rows).unwrap().wait().unwrap();
        assert_eq!(got.perfs, want.perfs, "a quiet chaos submit must be invisible");
    }
}
