//! PJRT runtime: loads the AOT-compiled surface artifacts and executes
//! them from the tuning hot path. Python never runs here — the artifacts
//! under `artifacts/*.hlo.txt` were lowered once by `make artifacts`
//! (python/compile/aot.py) and this module is pure rust + XLA.
//!
//! * [`shapes`] — the artifact input table, mirroring
//!   `python/compile/model.py::INPUT_SPEC` (kept in sync by the golden
//!   integration test).
//! * [`engine`] — PJRT CPU client, per-bucket compiled executables, and
//!   the batched `evaluate` entry point with greedy multi-bucket
//!   decomposition of odd batch sizes.
//! * [`golden`] — the patterned-input golden vectors shared with
//!   python/compile/aot.py, proving the rust<->python round trip.

pub mod engine;
pub mod golden;
pub mod shapes;

pub use engine::{Engine, EngineStats, EvalRequest, Perf, PreparedCall, SurfaceParams};
pub use shapes::{BUCKETS, D_PAD, E_DIM, G, J, R, RG, W_DIM};
