//! The execution runtime: evaluates the golden performance surface from
//! the tuning hot path, behind a pluggable backend (see `README.md` in
//! this directory).
//!
//! * [`backend`] — the [`backend::ExecBackend`] abstraction and
//!   [`backend::BackendKind`] selection (CLI `--backend`, env
//!   `ACTS_BACKEND`, auto fallback).
//! * [`pjrt`] — the PJRT backend: loads the AOT-compiled surface
//!   artifacts (`artifacts/*.hlo.txt`, lowered once by `make artifacts`
//!   / python/compile/aot.py) and executes them with greedy static-
//!   bucket decomposition. Python never runs here.
//! * [`native`] — the pure-`std` CPU backend: evaluates the same golden
//!   surface (the model in python/compile/kernels/ref.py) directly in
//!   f32, parallelised with `std::thread::scope` — no artifacts, no
//!   vendor binding, runs anywhere.
//! * [`engine`] — the backend-agnostic front-end: validation, the
//!   prepared-constant cache, cross-request coalescing (synchronous
//!   and overlapped via the backend [`backend::ExecBackend::submit`]
//!   path), telemetry, and the [`engine::RetryPolicy`] retry/deadline
//!   layer that absorbs transient backend faults below the session
//!   layer.
//! * [`chaos`] — deterministic fault injection: a
//!   [`chaos::ChaosBackend`] wrapper that perturbs any inner backend
//!   according to a seeded [`chaos::FaultPlan`] (transient/persistent
//!   errors, latency spikes, hangs, panics) — the harness behind the
//!   fault-tolerance tests and the CI chaos smoke.
//! * [`simd`] — SIMD dispatch for the native backend: the AVX2+FMA
//!   f32x8 row kernel, `ACTS_NATIVE_SIMD` mode parsing, and the
//!   construction-time [`simd::Dispatch`] resolution that keeps
//!   per-row results bitwise batch-invariant and deterministic.
//! * [`conformance`] — the reusable backend conformance suite: the
//!   checklist (golden parity, bitwise invariance/determinism, cost
//!   accounting, foreign-prepared rejection) any [`backend::ExecBackend`]
//!   — including future GPU/real-PJRT ones — must pass.
//! * [`shapes`] — the artifact input table, mirroring
//!   `python/compile/model.py::INPUT_SPEC` (kept in sync by the golden
//!   integration test).
//! * [`golden`] — the patterned-input golden vectors shared with
//!   python/compile/aot.py, proving the rust<->python round trip for
//!   both backends.

pub mod backend;
pub mod chaos;
pub mod conformance;
pub mod engine;
pub mod golden;
pub mod native;
pub mod pjrt;
pub mod shapes;
pub mod simd;

pub use backend::{BackendKind, ExecBackend, PendingExecution};
pub use chaos::{ChaosBackend, ChaosStats, Fault, FaultPlan};
pub use conformance::SuiteOptions;
pub use engine::{
    Engine, EngineStats, EvalRequest, Perf, PreparedCall, RetryPolicy, SurfaceParams,
};
pub use native::NativeBackend;
pub use shapes::{BUCKETS, D_PAD, E_DIM, G, J, R, RG, W_DIM};
pub use simd::{Dispatch, SimdMode};
