//! Golden-vector support: regenerate the patterned inputs that
//! `python/compile/aot.py::golden_inputs` produced, and parse the
//! expected outputs it wrote to `artifacts/golden_surface.txt`.
//!
//! The pattern is the cross-language contract (keep in sync with aot.py):
//!
//! ```text
//! raw(i, k) = sin(0.1 k + 0.7 i)        i = input index, k = flat index
//! u         = 0.5 + 0.5 raw
//! inv_rho2  = 2 |raw| + 0.1
//! step_s, cliff_kappa, gate_kappa = 5 raw
//! consts    = [50+40 raw0, 1+|raw1|, 10|raw2|+1, 100|raw3|+10]
//! otherwise = 0.5 raw
//! ```
//! All math in f64, cast to f32 at the end — both sides.

use super::engine::SurfaceParams;
use super::shapes::{self, D_PAD, E_DIM, W_DIM};
use crate::error::{ActsError, Result};
use std::path::Path;

/// Generate the patterned array for input `idx` at batch `b`.
pub fn pattern_input(idx: usize, b: usize) -> Vec<f32> {
    let (name, _) = shapes::INPUT_SPEC[idx];
    let n = shapes::len_for(idx, b);
    let raw = |k: usize| ((0.1 * k as f64) + 0.7 * idx as f64).sin();
    (0..n)
        .map(|k| {
            let r = raw(k);
            let v = match name {
                "u" => 0.5 + 0.5 * r,
                "inv_rho2" => 2.0 * r.abs() + 0.1,
                "step_s" | "cliff_kappa" | "gate_kappa" => 5.0 * r,
                "consts" => match k {
                    0 => 50.0 + 40.0 * r,
                    1 => 1.0 + r.abs(),
                    2 => 10.0 * r.abs() + 1.0,
                    _ => 100.0 * r.abs() + 10.0,
                },
                _ => 0.5 * r,
            };
            v as f32
        })
        .collect()
}

/// The full patterned call: (configs, w, e, params) for batch `b`.
pub fn pattern_call(b: usize) -> (Vec<Vec<f32>>, Vec<f32>, Vec<f32>, SurfaceParams) {
    let u_flat = pattern_input(0, b);
    let configs: Vec<Vec<f32>> = u_flat.chunks(D_PAD).map(|c| c.to_vec()).collect();
    let w = pattern_input(1, b);
    let e = pattern_input(2, b);
    debug_assert_eq!(w.len(), W_DIM);
    debug_assert_eq!(e.len(), E_DIM);
    let mut p = SurfaceParams::zeros();
    {
        let consts = pattern_input(19, b);
        p.consts.copy_from_slice(&consts);
    }
    p.m = pattern_input(3, b);
    p.step_s = pattern_input(4, b);
    p.step_t = pattern_input(5, b);
    p.qs = pattern_input(6, b);
    p.centers = pattern_input(7, b);
    p.inv_rho2 = pattern_input(8, b);
    p.amps_w = pattern_input(9, b);
    p.dirs = pattern_input(10, b);
    p.cliff_tau = pattern_input(11, b);
    p.cliff_kappa = pattern_input(12, b);
    p.cliff_gain_w = pattern_input(13, b);
    p.cliff_gain_e = pattern_input(14, b);
    p.gate_tau = pattern_input(15, b);
    p.gate_kappa = pattern_input(16, b);
    p.gate_floor_w = pattern_input(17, b);
    p.dep_w = pattern_input(18, b);
    (configs, w, e, p)
}

/// One golden case parsed from `golden_surface.txt`.
#[derive(Clone, Debug)]
pub struct GoldenCase {
    /// Batch size.
    pub b: usize,
    /// (input name, sum of all elements) — input-generation checksums.
    pub insums: Vec<(String, f64)>,
    /// Expected throughputs.
    pub thr: Vec<f64>,
    /// Expected latencies.
    pub lat: Vec<f64>,
}

/// Parse every case from a golden file.
pub fn parse_golden(path: impl AsRef<Path>) -> Result<Vec<GoldenCase>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| ActsError::io(path.display().to_string(), e))?;
    let mut cases: Vec<GoldenCase> = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let tag = it.next().expect("non-empty line");
        let bad = |what: &str| {
            ActsError::Artifact(format!("golden {}:{}: {what}", path.display(), ln + 1))
        };
        match tag {
            "case" => {
                let b: usize =
                    it.next().ok_or_else(|| bad("missing batch"))?.parse().map_err(|_| bad("bad batch"))?;
                cases.push(GoldenCase { b, insums: Vec::new(), thr: Vec::new(), lat: Vec::new() });
            }
            "insum" => {
                let case = cases.last_mut().ok_or_else(|| bad("insum before case"))?;
                let name = it.next().ok_or_else(|| bad("missing name"))?.to_string();
                let val: f64 =
                    it.next().ok_or_else(|| bad("missing value"))?.parse().map_err(|_| bad("bad value"))?;
                case.insums.push((name, val));
            }
            "thr" | "lat" => {
                let case = cases.last_mut().ok_or_else(|| bad("values before case"))?;
                let vals: std::result::Result<Vec<f64>, _> = it.map(|v| v.parse()).collect();
                let vals = vals.map_err(|_| bad("bad float"))?;
                if tag == "thr" {
                    case.thr = vals;
                } else {
                    case.lat = vals;
                }
            }
            other => return Err(bad(&format!("unknown tag {other}"))),
        }
    }
    Ok(cases)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_is_deterministic_and_sized() {
        for idx in 0..shapes::INPUT_SPEC.len() {
            let a = pattern_input(idx, 16);
            let b = pattern_input(idx, 16);
            assert_eq!(a, b);
            assert_eq!(a.len(), shapes::len_for(idx, 16));
        }
    }

    #[test]
    fn pattern_u_in_unit_range() {
        let u = pattern_input(0, 16);
        assert!(u.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn pattern_inv_rho2_positive() {
        let v = pattern_input(8, 1);
        assert!(v.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn pattern_call_shapes() {
        let (configs, w, e, p) = pattern_call(16);
        assert_eq!(configs.len(), 16);
        assert!(configs.iter().all(|c| c.len() == D_PAD));
        assert_eq!(w.len(), W_DIM);
        assert_eq!(e.len(), E_DIM);
        p.validate().unwrap();
    }

    #[test]
    fn parse_golden_roundtrip_synthetic() {
        let dir = std::env::temp_dir().join("acts_golden_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        std::fs::write(&path, "# c\ncase 2\ninsum u 1.5\nthr 1.0 2.0\nlat 3.0 4.0\n").unwrap();
        let cases = parse_golden(&path).unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].b, 2);
        assert_eq!(cases[0].insums, vec![("u".to_string(), 1.5)]);
        assert_eq!(cases[0].thr, vec![1.0, 2.0]);
        assert_eq!(cases[0].lat, vec![3.0, 4.0]);
    }

    #[test]
    fn parse_golden_rejects_garbage() {
        let dir = std::env::temp_dir().join("acts_golden_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "wat 1 2\n").unwrap();
        assert!(parse_golden(&path).is_err());
    }
}
