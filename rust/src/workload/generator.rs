//! Operation-stream generation and trace-derived workloads.
//!
//! Two uses:
//! * the staged test in the simulated staging environment replays a
//!   generated op stream (log replay, §4.2) to derive its per-request
//!   latency distribution;
//! * [`TraceWorkload`] closes the loop for real applications — given a
//!   recorded trace it *measures* the op mix and key skew and produces
//!   the `WorkloadSpec` feature vector, so a user can tune under "the
//!   workload my production logs actually show".

use super::zipf::Zipf;
use super::{feat, WorkloadSpec, W_FEATURES};
use crate::util::rng::Rng64;

/// Operation kind in a trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Point read.
    Read,
    /// Write / update.
    Write,
    /// Range scan.
    Scan,
}

/// One traced operation.
#[derive(Clone, Copy, Debug)]
pub struct Op {
    /// Kind.
    pub kind: OpKind,
    /// Key (rank-ordered: 0 most popular under zipfian generation).
    pub key: u64,
    /// Payload bytes.
    pub size: u32,
}

/// Generates op streams matching a [`WorkloadSpec`].
pub struct OpStreamGenerator {
    spec: WorkloadSpec,
    zipf: Option<Zipf>,
    keyspace: u64,
    rng: Rng64,
}

impl OpStreamGenerator {
    /// New generator over `keyspace` keys, seeded deterministically.
    pub fn new(spec: WorkloadSpec, keyspace: u64, seed: u64) -> OpStreamGenerator {
        let skew = spec.features()[feat::SKEW] as f64;
        // map skew feature [0,1] -> zipf theta (0 = uniform sampling)
        let zipf = if skew > 0.05 { Some(Zipf::new(keyspace, 0.4 + skew)) } else { None };
        OpStreamGenerator { spec, zipf, keyspace, rng: Rng64::new(seed) }
    }

    /// The spec this generator realises.
    pub fn spec(&self) -> &WorkloadSpec {
        &self.spec
    }

    /// Generate the next operation.
    pub fn next_op(&mut self) -> Op {
        let f = self.spec.features();
        let (r, w) = (f[feat::READ] as f64, f[feat::WRITE] as f64);
        let total = (r + w + f[feat::SCAN] as f64).max(1e-9);
        let x = self.rng.f64() * total;
        let kind = if x < r {
            OpKind::Read
        } else if x < r + w {
            OpKind::Write
        } else {
            OpKind::Scan
        };
        let key = match &self.zipf {
            Some(z) => z.sample(&mut self.rng),
            None => self.rng.below(self.keyspace),
        };
        let mean_size = 64.0 + 4096.0 * f[feat::SIZE] as f64;
        let size = (mean_size * (0.5 + self.rng.f64())) as u32;
        Op { kind, key, size }
    }

    /// Generate `n` operations.
    pub fn take(&mut self, n: usize) -> Vec<Op> {
        (0..n).map(|_| self.next_op()).collect()
    }
}

/// Built-in recorded traces, nameable as scenarios (`trace:<name>` via
/// [`WorkloadSpec::by_name`], so `acts fleet --workloads
/// trace:hot-reads` sweeps a log-replay workload like any declared
/// one). Each name replays a fixed recorded op stream (deterministic
/// generator seed) and *measures* its features through
/// [`TraceWorkload::from_ops`] — the §4.2 log-replay path end to end,
/// rather than a hand-declared feature vector.
pub const TRACE_NAMES: &[&str] = &["trace:hot-reads", "trace:flash-sale", "trace:nightly-etl"];

/// Ops per built-in recorded trace (enough for stable feature
/// estimates; generation is deterministic and cheap).
const TRACE_OPS: usize = 40_000;

/// Resolve a built-in recorded trace by `trace:<name>` (see
/// [`TRACE_NAMES`]); `None` for unknown names.
pub fn trace_by_name(name: &str) -> Option<WorkloadSpec> {
    // (underlying "production" mix the trace was recorded from,
    //  keyspace, recording seed, staged-test duration)
    let (features, keyspace, seed, duration_s) = match name {
        // a read-mostly cache-hot service: heavy zipfian point reads
        "trace:hot-reads" => ([0.92, 0.08, 0.0, 0.97, 0.25, 0.55, 0.1, 1.0], 50_000, 0x7A1, 120.0),
        // a checkout burst: write-heavy, hot SKUs, high concurrency
        "trace:flash-sale" => ([0.55, 0.42, 0.03, 0.85, 0.4, 0.95, 0.15, 1.0], 20_000, 0x7A2, 60.0),
        // a reporting batch: long scans over a cold, unskewed keyspace
        "trace:nightly-etl" => ([0.08, 0.12, 0.8, 0.02, 0.9, 0.3, 0.6, 1.0], 10_000, 0x7A3, 1800.0),
        _ => return None,
    };
    let recorded = WorkloadSpec::from_features("recorded", features);
    let mut gen = OpStreamGenerator::new(recorded, keyspace, seed);
    let ops = gen.take(TRACE_OPS);
    Some(TraceWorkload::from_ops(name, &ops, keyspace).with_duration(duration_s))
}

/// A workload derived from a recorded trace (measured features).
pub struct TraceWorkload;

impl TraceWorkload {
    /// Estimate a [`WorkloadSpec`] from a trace. Skew is estimated from
    /// the fraction of accesses hitting the top 1% of observed keys
    /// (inverted through the same mapping the generator uses).
    pub fn from_ops(name: &str, ops: &[Op], keyspace: u64) -> WorkloadSpec {
        assert!(!ops.is_empty(), "empty trace");
        let n = ops.len() as f32;
        let mut reads = 0f32;
        let mut writes = 0f32;
        let mut scans = 0f32;
        let mut size_sum = 0f64;
        let mut counts = std::collections::HashMap::<u64, u32>::new();
        for op in ops {
            match op.kind {
                OpKind::Read => reads += 1.0,
                OpKind::Write => writes += 1.0,
                OpKind::Scan => scans += 1.0,
            }
            size_sum += op.size as f64;
            *counts.entry(op.key).or_insert(0) += 1;
        }
        // head mass: fraction of ops on the top-1%-of-keyspace hottest keys
        let mut freqs: Vec<u32> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let head_keys = ((keyspace as f64) * 0.01).ceil() as usize;
        let head_mass: f64 = freqs.iter().take(head_keys).map(|&c| c as f64).sum::<f64>()
            / ops.len() as f64;
        // uniform head mass would be ~1%; map [0.01, 0.8] -> skew [0, 1]
        let skew = (((head_mass - 0.01) / 0.79).clamp(0.0, 1.0)) as f32;

        let mean_size = size_sum / ops.len() as f64;
        let size_feat = (((mean_size - 64.0) / 4096.0).clamp(0.0, 1.0)) as f32;

        let mut f = [0f32; W_FEATURES];
        f[feat::READ] = reads / n;
        f[feat::WRITE] = writes / n;
        f[feat::SCAN] = scans / n;
        f[feat::SKEW] = skew;
        f[feat::SIZE] = size_feat;
        f[feat::CONCURRENCY] = 0.5;
        f[feat::COMPUTE] = 0.1 + 0.4 * (scans / n);
        WorkloadSpec::from_features(name, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_respects_op_mix() {
        let mut g = OpStreamGenerator::new(WorkloadSpec::zipfian_read_write(), 10_000, 7);
        let ops = g.take(20_000);
        let reads = ops.iter().filter(|o| o.kind == OpKind::Read).count() as f64;
        let frac = reads / ops.len() as f64;
        assert!((0.7..0.8).contains(&frac), "read fraction {frac}");
    }

    #[test]
    fn uniform_spec_uses_uniform_keys() {
        let mut g = OpStreamGenerator::new(WorkloadSpec::uniform_read(), 1000, 8);
        let ops = g.take(50_000);
        let head = ops.iter().filter(|o| o.key < 10).count() as f64 / ops.len() as f64;
        assert!(head < 0.03, "uniform head mass {head}");
    }

    #[test]
    fn trace_roundtrip_recovers_features() {
        // generate from a known spec, re-estimate, compare key features
        let spec = WorkloadSpec::zipfian_read_write();
        let mut g = OpStreamGenerator::new(spec.clone(), 10_000, 9);
        let ops = g.take(50_000);
        let est = TraceWorkload::from_ops("estimated", &ops, 10_000);
        let (f0, f1) = (spec.features(), est.features());
        assert!((f0[feat::READ] - f1[feat::READ]).abs() < 0.05);
        assert!((f0[feat::WRITE] - f1[feat::WRITE]).abs() < 0.05);
        assert!(f1[feat::SKEW] > 0.4, "skew underestimated: {}", f1[feat::SKEW]);
        assert_eq!(f1[feat::BIAS], 1.0);
    }

    #[test]
    fn trace_of_uniform_reads_is_unskewed() {
        let mut g = OpStreamGenerator::new(WorkloadSpec::uniform_read(), 10_000, 10);
        let ops = g.take(50_000);
        let est = TraceWorkload::from_ops("est", &ops, 10_000);
        assert!(est.features()[feat::SKEW] < 0.1);
        assert!(est.features()[feat::READ] > 0.95);
    }

    #[test]
    fn trace_registry_resolves_measured_workloads() {
        for name in TRACE_NAMES {
            let w = trace_by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(&w.name, name, "trace name must round-trip");
            assert_eq!(w.features()[feat::BIAS], 1.0);
            // measured features are fractions: in bounds, mix sums to 1
            let f = w.features();
            assert!(f.iter().all(|&x| (0.0..=1.0).contains(&x)), "{name}: {f:?}");
            let mix = f[feat::READ] + f[feat::WRITE] + f[feat::SCAN];
            assert!((0.99..=1.01).contains(&mix), "{name}: mix {mix}");
        }
        assert!(trace_by_name("trace:nope").is_none());
        assert!(trace_by_name("hot-reads").is_none(), "the prefix is part of the name");
    }

    #[test]
    fn traces_measure_their_recorded_character() {
        let hot = trace_by_name("trace:hot-reads").unwrap();
        assert!(hot.features()[feat::READ] > 0.85, "{:?}", hot.features());
        assert!(hot.features()[feat::SKEW] > 0.4, "skew {:?}", hot.features()[feat::SKEW]);
        let etl = trace_by_name("trace:nightly-etl").unwrap();
        assert!(etl.features()[feat::SCAN] > 0.7, "{:?}", etl.features());
        assert!(etl.features()[feat::SKEW] < 0.1, "{:?}", etl.features()[feat::SKEW]);
        assert_eq!(etl.duration_s, 1800.0, "trace duration must stick");
        let sale = trace_by_name("trace:flash-sale").unwrap();
        assert!(sale.features()[feat::WRITE] > 0.3, "{:?}", sale.features());
    }

    #[test]
    fn trace_resolution_is_deterministic() {
        let a = trace_by_name("trace:hot-reads").unwrap();
        let b = trace_by_name("trace:hot-reads").unwrap();
        assert_eq!(a, b, "same recorded stream, same measured features");
    }

    #[test]
    fn generator_is_deterministic() {
        let mk = || {
            let mut g = OpStreamGenerator::new(WorkloadSpec::page_mix(), 100, 11);
            g.take(100).iter().map(|o| o.key).collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }
}
