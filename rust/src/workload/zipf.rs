//! Zipfian key-popularity sampling for the op-stream generator.
//!
//! Implements the rejection-inversion method of Hörmann & Derflinger
//! ("Rejection-inversion to generate variates from monotone discrete
//! distributions", 1996) — the same algorithm YCSB-style generators use,
//! O(1) per sample for any exponent theta > 0, theta != 1 handled too.

use crate::util::rng::Rng64;

/// Zipf(n, theta) sampler over keys `0..n` (0 most popular,
/// p(rank k) proportional to (k+1)^-theta).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    theta: f64,
    // precomputed constants (Hörmann & Derflinger's notation, over the
    // internal 1-based rank domain [0.5, n + 0.5])
    h_x1: f64,
    h_n: f64,
    s: f64,
}

impl Zipf {
    /// New sampler over `n` keys with skew `theta` (> 0). `theta` near 0
    /// approaches uniform; YCSB default is 0.99.
    pub fn new(n: u64, theta: f64) -> Zipf {
        assert!(n >= 1, "zipf over empty key space");
        assert!(theta > 0.0, "theta must be > 0");
        let h_x1 = h_integral(1.5, theta) - 1.0;
        let h_n = h_integral(n as f64 + 0.5, theta);
        let s = 2.0 - h_integral_inverse(h_integral(2.5, theta) - h(2.0, theta), theta);
        Zipf { n, theta, h_x1, h_n, s }
    }

    /// Draw one key in `0..n`.
    pub fn sample(&self, rng: &mut Rng64) -> u64 {
        loop {
            let u = self.h_n + rng.f64() * (self.h_x1 - self.h_n);
            let x = h_integral_inverse(u, self.theta);
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            if k - x <= self.s
                || u >= h_integral(k + 0.5, self.theta) - h(k, self.theta)
            {
                return k as u64 - 1; // 1-based rank -> 0-based key
            }
        }
    }

    /// Number of keys.
    pub fn n(&self) -> u64 {
        self.n
    }
}

/// h(x) = x^-theta.
fn h(x: f64, theta: f64) -> f64 {
    (-theta * x.ln()).exp()
}

/// H(x) = integral of h = (x^(1-theta) - 1)/(1-theta); ln(x) at theta=1.
fn h_integral(x: f64, theta: f64) -> f64 {
    let log_x = x.ln();
    helper2((1.0 - theta) * log_x) * log_x
}

/// H^-1(x).
fn h_integral_inverse(x: f64, theta: f64) -> f64 {
    let mut t = x * (1.0 - theta);
    if t < -1.0 {
        t = -1.0; // guard rounding at the domain edge
    }
    (helper1(t) * x).exp()
}

/// ln(1+x)/x, stable near 0.
fn helper1(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.ln_1p() / x
    } else {
        1.0 - x * (0.5 - x * (1.0 / 3.0 - 0.25 * x))
    }
}

/// (exp(x)-1)/x, stable near 0.
fn helper2(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.exp_m1() / x
    } else {
        1.0 + x * 0.5 * (1.0 + x / 3.0 * (1.0 + 0.25 * x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head_mass(theta: f64, n: u64, draws: usize) -> f64 {
        let z = Zipf::new(n, theta);
        let mut rng = Rng64::new(42);
        let head = n / 100; // top 1%
        let mut hits = 0usize;
        for _ in 0..draws {
            if z.sample(&mut rng) <= head {
                hits += 1;
            }
        }
        hits as f64 / draws as f64
    }

    #[test]
    fn high_theta_concentrates_mass() {
        let skewed = head_mass(0.99, 10_000, 20_000);
        let mild = head_mass(0.2, 10_000, 20_000);
        assert!(skewed > 0.3, "top-1% mass {skewed} too small for theta=0.99");
        assert!(mild < 0.12, "top-1% mass {mild} too large for theta=0.2");
        assert!(skewed > mild * 2.0);
    }

    #[test]
    fn samples_in_range() {
        let z = Zipf::new(100, 0.99);
        let mut rng = Rng64::new(1);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 100);
        }
    }

    #[test]
    fn theta_one_works() {
        let z = Zipf::new(1000, 1.0);
        let mut rng = Rng64::new(2);
        let mut first = 0;
        for _ in 0..10_000 {
            if z.sample(&mut rng) == 0 {
                first += 1;
            }
        }
        // p(0) = 1/H(1000) ~ 1/7.49 ~ 0.134
        let p = first as f64 / 10_000.0;
        assert!((0.09..0.18).contains(&p), "p(0) = {p}");
    }

    #[test]
    fn single_key_space() {
        let z = Zipf::new(1, 0.99);
        let mut rng = Rng64::new(3);
        assert_eq!(z.sample(&mut rng), 0);
    }

    #[test]
    fn monotone_rank_frequency() {
        let z = Zipf::new(50, 0.99);
        let mut rng = Rng64::new(4);
        let mut counts = vec![0u32; 50];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        // rank 0 must dominate rank 10 must dominate rank 40
        assert!(counts[0] > counts[10] && counts[10] > counts[40], "{counts:?}");
    }
}
