//! The workload generator — one of the three components of the paper's
//! flexible architecture (Fig. 2). Satisfies *workload scalability*: new
//! workloads integrate by declaring a [`WorkloadSpec`] (or by deriving
//! one from a recorded operation trace, the staging-environment log
//! replay of §4.2), and the tuner never sees anything but the trait.
//!
//! A workload is summarised by an 8-feature vector fed to the surface
//! artifact (DESIGN.md §3): the performance model is workload-dependent
//! exactly as §2.2 requires — the same SUT under uniform-read vs zipfian
//! read-write produces different surfaces (Fig. 1a vs 1d).

pub mod generator;
pub mod zipf;

pub use generator::{Op, OpKind, OpStreamGenerator, TraceWorkload};

/// Workload feature vector width (mirrors the artifact's W).
pub const W_FEATURES: usize = 8;

/// Feature indices (artifact contract).
pub mod feat {
    /// Fraction of point reads.
    pub const READ: usize = 0;
    /// Fraction of writes.
    pub const WRITE: usize = 1;
    /// Fraction of scans.
    pub const SCAN: usize = 2;
    /// Key skew: 0 = uniform, ~1 = heavy zipfian.
    pub const SKEW: usize = 3;
    /// Normalised request payload size.
    pub const SIZE: usize = 4;
    /// Normalised offered concurrency.
    pub const CONCURRENCY: usize = 5;
    /// Compute intensity (analytics-ness).
    pub const COMPUTE: usize = 6;
    /// Constant bias lane (always 1.0).
    pub const BIAS: usize = 7;
}

/// A declarative workload description.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    /// Name for reports (e.g. `uniform-read`).
    pub name: String,
    features: [f32; W_FEATURES],
    /// Nominal test duration in simulated seconds (staged-test cost).
    pub duration_s: f64,
    /// Interactions per transaction (Table 1 reports both Txns/s and
    /// Hits/s; hits = txns * hits_per_txn).
    pub hits_per_txn: f64,
}

impl WorkloadSpec {
    /// Build from raw features (bias lane is forced to 1).
    pub fn from_features(name: &str, mut features: [f32; W_FEATURES]) -> WorkloadSpec {
        features[feat::BIAS] = 1.0;
        WorkloadSpec { name: name.into(), features, duration_s: 300.0, hits_per_txn: 3.3 }
    }

    /// The artifact-facing feature vector.
    pub fn features(&self) -> &[f32; W_FEATURES] {
        &self.features
    }

    /// Builder: staged-test duration.
    pub fn with_duration(mut self, seconds: f64) -> Self {
        self.duration_s = seconds;
        self
    }

    /// Builder: hits per transaction.
    pub fn with_hits_per_txn(mut self, h: f64) -> Self {
        self.hits_per_txn = h;
        self
    }

    // --- the paper's workloads -------------------------------------------

    /// YCSB-style uniform point reads (Fig. 1a): `query_cache_type`
    /// dominates MySQL here.
    pub fn uniform_read() -> WorkloadSpec {
        Self::from_features("uniform-read", [1.0, 0.0, 0.0, 0.0, 0.3, 0.5, 0.1, 1.0])
    }

    /// YCSB-style zipfian read-write mix (Fig. 1d, §5.1's cloud
    /// application workload).
    pub fn zipfian_read_write() -> WorkloadSpec {
        Self::from_features("zipfian-rw", [0.75, 0.25, 0.0, 0.9, 0.35, 0.6, 0.15, 1.0])
    }

    /// Write-heavy ingest.
    pub fn write_heavy() -> WorkloadSpec {
        Self::from_features("write-heavy", [0.1, 0.9, 0.0, 0.4, 0.5, 0.7, 0.1, 1.0])
    }

    /// Scan-heavy reporting.
    pub fn scan_heavy() -> WorkloadSpec {
        Self::from_features("scan-heavy", [0.2, 0.05, 0.75, 0.2, 0.8, 0.3, 0.4, 1.0])
    }

    /// Web page mix for Tomcat (Fig. 1b / Table 1): bursty, sessionful.
    pub fn page_mix() -> WorkloadSpec {
        Self::from_features("page-mix", [0.85, 0.15, 0.0, 0.6, 0.45, 0.85, 0.25, 1.0])
            .with_hits_per_txn(3.3)
    }

    /// Batch analytics for Spark (Fig. 1c/1f).
    pub fn batch_analytics() -> WorkloadSpec {
        Self::from_features("batch-analytics", [0.3, 0.1, 0.5, 0.1, 0.9, 0.4, 0.95, 1.0])
            .with_duration(900.0)
    }

    /// All built-in workloads (CLI registry). `trace:<name>` resolves a
    /// recorded operation trace through the log-replay path
    /// ([`generator::trace_by_name`]): the op stream is replayed and
    /// its features *measured* rather than declared, so trace-derived
    /// workloads are nameable scenarios like any other (`acts fleet
    /// --workloads trace:hot-reads`).
    pub fn by_name(name: &str) -> Option<WorkloadSpec> {
        if name.starts_with("trace:") {
            return generator::trace_by_name(name);
        }
        match name {
            "uniform-read" => Some(Self::uniform_read()),
            "zipfian-rw" => Some(Self::zipfian_read_write()),
            "write-heavy" => Some(Self::write_heavy()),
            "scan-heavy" => Some(Self::scan_heavy()),
            "page-mix" => Some(Self::page_mix()),
            "batch-analytics" => Some(Self::batch_analytics()),
            _ => None,
        }
    }

    /// Registry names (declared workloads first, then the built-in
    /// recorded traces — [`generator::TRACE_NAMES`]).
    pub const NAMES: &'static [&'static str] = &[
        "uniform-read",
        "zipfian-rw",
        "write-heavy",
        "scan-heavy",
        "page-mix",
        "batch-analytics",
        "trace:hot-reads",
        "trace:flash-sale",
        "trace:nightly-etl",
    ];
}

/// Deployment environment features (mirrors the artifact's E): the §2.2
/// finding that deployments change the surface (Fig. 1c vs 1f).
#[derive(Clone, Debug, PartialEq)]
pub struct DeploymentEnv {
    /// Name for reports (e.g. `cluster-8`).
    pub name: String,
    features: [f32; 4],
}

/// Deployment feature indices.
pub mod dep {
    /// Cluster scale: 0 = standalone, ->1 large cluster.
    pub const CLUSTER: usize = 0;
    /// Normalised cores per node.
    pub const CORES: usize = 1;
    /// Normalised memory per node.
    pub const MEMORY: usize = 2;
    /// Co-deployed interference pressure.
    pub const INTERFERENCE: usize = 3;
}

impl DeploymentEnv {
    /// Build from raw features.
    pub fn from_features(name: &str, features: [f32; 4]) -> DeploymentEnv {
        DeploymentEnv { name: name.into(), features }
    }

    /// The artifact-facing feature vector.
    pub fn features(&self) -> &[f32; 4] {
        &self.features
    }

    /// Single beefy server (Fig. 1c).
    pub fn standalone() -> DeploymentEnv {
        Self::from_features("standalone", [0.0, 0.5, 0.5, 0.0])
    }

    /// An `n`-node cluster (Fig. 1f). Scale saturates around 32 nodes.
    pub fn cluster(n: usize) -> DeploymentEnv {
        let scale = (n as f32 / 32.0).min(1.0);
        Self::from_features(&format!("cluster-{n}"), [scale, 0.5, 0.5, 0.1])
    }

    /// The §5.2 ARM virtual machine: modest cores, network-partitioned.
    pub fn arm_vm() -> DeploymentEnv {
        Self::from_features("arm-vm", [0.1, 0.25, 0.3, 0.2])
    }

    /// Raise interference (co-deployed software pressure, §2.2).
    pub fn with_interference(mut self, level: f32) -> Self {
        self.features[dep::INTERFERENCE] = level.clamp(0.0, 1.0);
        self
    }

    /// Resolve a deployment by registry name, so deployments are
    /// nameable from the CLI and scenario specs:
    ///
    /// * `standalone`, `arm-vm` — the fixed environments;
    /// * `cluster-<n>` — an n-node cluster, e.g. `cluster-8`;
    /// * `<deployment>-interference-<f>` — any of the above with the
    ///   interference feature pinned to `f` in `[0, 1]`, e.g.
    ///   `arm-vm-interference-0.55` (the §5.2 fully-utilised VM).
    ///
    /// Round-trips: the resolved environment's `name` is the input
    /// string verbatim.
    pub fn by_name(name: &str) -> Option<DeploymentEnv> {
        if let Some((base, level)) = name.rsplit_once("-interference-") {
            let level: f32 = level.parse().ok()?;
            if !(0.0..=1.0).contains(&level) {
                return None;
            }
            let mut d = Self::by_name(base)?.with_interference(level);
            d.name = name.to_string();
            return Some(d);
        }
        match name {
            "standalone" => Some(Self::standalone()),
            "arm-vm" => Some(Self::arm_vm()),
            _ => name
                .strip_prefix("cluster-")
                .and_then(|n| n.parse::<usize>().ok())
                .map(Self::cluster),
        }
    }

    /// Registry name patterns (`acts list deployments`).
    pub const NAME_PATTERNS: &'static [&'static str] =
        &["standalone", "arm-vm", "cluster-<n>", "<deployment>-interference-<f>"];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_complete() {
        for name in WorkloadSpec::NAMES {
            let w = WorkloadSpec::by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(&w.name, name);
            assert_eq!(w.features()[feat::BIAS], 1.0, "{name} bias");
        }
        assert!(WorkloadSpec::by_name("nope").is_none());
    }

    #[test]
    fn trace_names_are_registered() {
        for name in generator::TRACE_NAMES {
            assert!(WorkloadSpec::NAMES.contains(name), "{name} missing from NAMES");
            let w = WorkloadSpec::by_name(name).unwrap_or_else(|| panic!("{name} must resolve"));
            assert_eq!(&w.name, name);
        }
        assert!(WorkloadSpec::by_name("trace:nope").is_none());
    }

    #[test]
    fn op_mix_fractions_are_sane() {
        for name in WorkloadSpec::NAMES {
            let w = WorkloadSpec::by_name(name).unwrap();
            let f = w.features();
            let mix = f[feat::READ] + f[feat::WRITE] + f[feat::SCAN];
            assert!((0.9..=1.1).contains(&mix), "{name} mix {mix}");
            assert!(f.iter().all(|&x| (0.0..=1.0).contains(&x)), "{name}");
        }
    }

    #[test]
    fn uniform_vs_zipfian_differ_in_skew() {
        let u = WorkloadSpec::uniform_read();
        let z = WorkloadSpec::zipfian_read_write();
        assert_eq!(u.features()[feat::SKEW], 0.0);
        assert!(z.features()[feat::SKEW] > 0.8);
    }

    #[test]
    fn deployments() {
        assert_eq!(DeploymentEnv::standalone().features()[dep::CLUSTER], 0.0);
        assert!(DeploymentEnv::cluster(8).features()[dep::CLUSTER] > 0.2);
        assert!(DeploymentEnv::cluster(64).features()[dep::CLUSTER] <= 1.0);
        let d = DeploymentEnv::standalone().with_interference(0.7);
        assert_eq!(d.features()[dep::INTERFERENCE], 0.7);
    }

    #[test]
    fn builders() {
        let w = WorkloadSpec::uniform_read().with_duration(60.0).with_hits_per_txn(5.0);
        assert_eq!(w.duration_s, 60.0);
        assert_eq!(w.hits_per_txn, 5.0);
    }

    #[test]
    fn deployment_registry_round_trips() {
        for name in [
            "standalone",
            "arm-vm",
            "cluster-8",
            "cluster-64",
            "standalone-interference-0.7",
            "arm-vm-interference-0.55",
            "cluster-8-interference-0.25",
        ] {
            let d = DeploymentEnv::by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(d.name, name, "registry name must round-trip");
        }
    }

    #[test]
    fn deployment_registry_matches_constructors() {
        assert_eq!(
            DeploymentEnv::by_name("standalone").unwrap().features(),
            DeploymentEnv::standalone().features()
        );
        assert_eq!(
            DeploymentEnv::by_name("arm-vm").unwrap().features(),
            DeploymentEnv::arm_vm().features()
        );
        assert_eq!(
            DeploymentEnv::by_name("cluster-8").unwrap().features(),
            DeploymentEnv::cluster(8).features()
        );
        assert_eq!(
            DeploymentEnv::by_name("arm-vm-interference-0.55").unwrap().features(),
            DeploymentEnv::arm_vm().with_interference(0.55).features()
        );
    }

    #[test]
    fn deployment_registry_rejects_garbage() {
        for name in [
            "nope",
            "cluster-",
            "cluster-x",
            "cluster--3",
            "standalone-interference-",
            "standalone-interference-abc",
            "standalone-interference-1.5",
            "standalone-interference--0.2",
            "nope-interference-0.5",
        ] {
            assert!(DeploymentEnv::by_name(name).is_none(), "`{name}` must not resolve");
        }
    }
}
